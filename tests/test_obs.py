"""The PR 8 observability layer (repro/obs/, DESIGN.md §13): span
tracing with dual clocks and Chrome export, the typed metrics registry
+ jsonl sink + Prometheus exposition, the MetricsLogger shim, live
invariant monitors, artifact validation, and the traced smokes whose
``fleet.tier_bits`` / ``train.bits_sent`` totals must reconcile
exactly with the engines' own ledgers."""
import json
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.obs import monitors as obs_monitors
from repro.obs import provenance as obs_provenance
from repro.obs import trace as obs_trace
from repro.obs import validate as obs_validate
from repro.obs.metrics import JsonlSink, Registry
from repro.obs.monitors import ObsWarning
from repro.training.metrics import MetricsLogger

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture
def tracer():
    """A fresh installed tracer, uninstalled afterwards."""
    t = obs_trace.configure(meta={"test": "obs"})
    yield t
    obs_trace.uninstall()


@pytest.fixture
def registry():
    """A fresh global registry, original restored afterwards."""
    old = obs_metrics.get_registry()
    reg = obs_metrics.set_registry(Registry())
    yield reg
    obs_metrics.set_registry(old)


# ----------------------------------------------------------------------
# trace: spans, clocks, export
# ----------------------------------------------------------------------

def test_disabled_tracing_is_a_shared_null_span():
    """With no tracer installed the module helpers are free: span()
    returns one shared singleton (no allocation) and instant/counter
    return immediately — the contract bench_obs.py prices."""
    obs_trace.uninstall()
    s1 = obs_trace.span("a", track="x", step=1)
    s2 = obs_trace.span("b")
    assert s1 is s2 is obs_trace._NULL_SPAN
    with s1 as sp:
        sp.set(anything=1)   # no-op, no error
    obs_trace.instant("nope")
    obs_trace.counter("nope", 1.0)
    obs_trace.set_virtual_time(3.0)
    assert not obs_trace.active()


def test_span_nesting_and_export_roundtrip(tracer, tmp_path):
    with obs_trace.span("outer", track="t", a=1) as outer:
        with obs_trace.span("inner", track="t"):
            pass
        outer.set(b=2)
    obs_trace.instant("tick", track="t", k="v")
    obs_trace.counter("depth", 3.0, track="t")
    # inner closes first (trace-event order), args accumulate on outer
    names = [e["name"] for e in tracer.events]
    assert names == ["inner", "outer", "tick", "depth"]
    outer_ev = tracer.events[1]
    assert outer_ev["args"] == {"a": 1, "b": 2}
    assert outer_ev["dur"] >= tracer.events[0]["dur"]

    path = os.path.join(tmp_path, "t.trace.json")
    assert obs_trace.export(path) == path
    with open(path) as f:
        doc = json.load(f)
    assert obs_validate.validate_trace(doc) == []
    assert doc["metadata"]["test"] == "obs"
    # thread-name metadata for the one track, on both clock pids
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert {(e["name"], e["pid"]) for e in meta} >= {
        ("thread_name", obs_trace.WALL_PID),
        ("thread_name", obs_trace.VIRTUAL_PID)}


def test_virtual_clock_emits_dual_pid_twins(tracer):
    """While a virtual time is published every event appears twice —
    wall pid 1 and virtual pid 2 with ts = virtual_seconds * 1e6."""
    obs_trace.set_virtual_time(2.0)
    with obs_trace.span("round", track="fleet"):
        obs_trace.set_virtual_time(5.0)
    obs_trace.counter("bits", 7.0, track="fleet")
    spans = [e for e in tracer.events if e["name"] == "round"]
    assert [e["pid"] for e in spans] == [obs_trace.WALL_PID,
                                         obs_trace.VIRTUAL_PID]
    vspan = spans[1]
    assert vspan["ts"] == pytest.approx(2.0 * 1e6)
    assert vspan["dur"] == pytest.approx(3.0 * 1e6)
    ctrs = [e for e in tracer.events if e["name"] == "bits"]
    assert {e["pid"] for e in ctrs} == {obs_trace.WALL_PID,
                                        obs_trace.VIRTUAL_PID}
    assert ctrs[1]["ts"] == pytest.approx(5.0 * 1e6)


def test_traced_decorator_and_export_without_tracer(tmp_path):
    obs_trace.uninstall()
    assert obs_trace.export(os.path.join(tmp_path, "x.json")) is None

    calls = []

    @obs_trace.traced("named.op", track="t")
    def op(x):
        calls.append(x)
        return x + 1

    assert op(1) == 2          # disabled: still just calls through
    t = obs_trace.configure()
    try:
        assert op(2) == 3
        assert [e["name"] for e in t.events] == ["named.op"]
    finally:
        obs_trace.uninstall()
    assert calls == [1, 2]


def test_kernel_scope_is_jit_compatible():
    """kernel_scope wraps jax.named_scope — must work under tracing."""
    @jax.jit
    def f(x):
        with obs_trace.kernel_scope("unit_test"):
            return x * 2.0

    assert float(f(jnp.float32(3.0))) == 6.0


# ----------------------------------------------------------------------
# metrics: registry, sink, exposition
# ----------------------------------------------------------------------

def test_registry_types_and_kind_mismatch(registry):
    c = registry.counter("a.hits")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    g = registry.gauge("a.level")
    g.set(4.0)
    g.inc()
    assert g.value == 5.0
    h = registry.histogram("a.lat")
    for v in [1.0, 2.0, 3.0, 4.0]:
        h.observe(v)
    h.observe(10.0, n=3)
    assert h.count == 7 and h.sum == pytest.approx(40.0)
    assert h.min == 1.0 and h.max == 10.0
    assert h.percentile(50) == 4.0
    # get-or-create returns the same object; kind mixups are errors
    assert registry.counter("a.hits") is c
    with pytest.raises(TypeError, match="counter"):
        registry.gauge("a.hits")
    with pytest.raises(TypeError, match="gauge"):
        registry.histogram("a.level")


def test_snapshot_validates_and_prometheus_exposition(registry, tmp_path):
    registry.counter("train.steps").inc(6)
    registry.gauge("fleet.tier_bits").set(128.0)
    registry.histogram("fleet.staleness").observe(1.0, n=4)
    path = os.path.join(tmp_path, "m.json")
    registry.write_snapshot(path, extra={"provenance": {"x": 1}})
    with open(path) as f:
        doc = json.load(f)
    assert obs_validate.validate_metrics(doc) == []
    assert doc["provenance"] == {"x": 1}
    assert doc["metrics"]["fleet.tier_bits"]["value"] == 128.0

    text = registry.to_prometheus()
    assert "# TYPE repro_train_steps counter" in text
    assert "repro_fleet_tier_bits 128.0" in text
    assert "repro_fleet_staleness_count 4" in text


def test_jsonl_sink_roundtrip_and_idempotent_close(tmp_path):
    path = os.path.join(tmp_path, "logs", "x.jsonl")
    sink = JsonlSink(path)     # creates parent dirs
    sink.write({"step": 0, "loss": 1.5})
    sink.write({"step": 1, "loss": 1.25})
    sink.close()
    sink.close()               # idempotent
    assert sink.closed
    with pytest.raises(ValueError, match="closed"):
        sink.write({"step": 2})
    with open(path) as f:
        recs = [json.loads(line) for line in f]
    assert [r["step"] for r in recs] == [0, 1]
    # append mode: a second sink extends, never truncates
    with JsonlSink(path) as sink2:
        sink2.write({"step": 2})
    with open(path) as f:
        assert len(f.readlines()) == 3


def test_metrics_logger_shim_roundtrip(tmp_path, capsys):
    """The MetricsLogger public contract (jsonl format, stdout lines,
    idempotent close) survives the PR 8 reroute through obs.metrics,
    and logged fields now mirror into the registry as gauges."""
    reg = Registry()
    lg = MetricsLogger(out_dir=str(tmp_path), name="train",
                       print_every=2, registry=reg)
    lg.log(0, loss=2.0, bits_sent=64, note="warm")
    lg.log(1, loss=1.5, bits_sent=32)
    lg.close()
    lg.close()                 # idempotent (pre-PR 8 double-closed a fd)

    with open(os.path.join(tmp_path, "train.jsonl")) as f:
        recs = [json.loads(line) for line in f]
    assert [r["step"] for r in recs] == [0, 1]
    assert recs[0]["loss"] == 2.0 and recs[0]["note"] == "warm"
    assert all("wall_s" in r for r in recs)
    # the registry mirror: latest value per field + the step gauge
    assert reg.gauge("train.step").value == 1.0
    assert reg.gauge("train.loss").value == 1.5
    assert reg.gauge("train.bits_sent").value == 32.0
    out = capsys.readouterr().out
    assert "[step      0]" in out and "loss=2" in out
    assert "[step      1]" not in out      # print_every=2


# ----------------------------------------------------------------------
# monitors
# ----------------------------------------------------------------------

class _FakeResult:
    """Minimal FleetRunResult stand-in for the ledger monitors."""

    def __init__(self, tier_bits, bits_cum, msg_bits):
        self.tier_bits = np.asarray(tier_bits, np.float64)
        self.bits_cum = np.asarray(bits_cum, np.float64)
        self.message_log = [type("M", (), {"bits": b})() for b in msg_bits]
        self.commit_log = []


def test_fleet_ledger_monitor_detects_tampering():
    good = _FakeResult([64.0, 32.0], [0.0, 96.0], [32.0])
    assert obs_monitors.check_fleet_ledger(good).ok
    # tamper the cumulative ledger: reconciliation must fire
    bad = _FakeResult([64.0, 32.0], [0.0, 97.0], [32.0])
    res = obs_monitors.check_fleet_ledger(bad)
    assert not res.ok
    assert "VIOLATED" in res.message()
    with pytest.warns(ObsWarning, match="fleet_ledger"):
        out = obs_monitors.emit([res], registry=Registry())
    assert out == [res]


def test_monitor_emit_counts_checks_and_failures(registry):
    good = _FakeResult([8.0], [0.0, 8.0], [])
    bad = _FakeResult([8.0], [0.0, 9.0], [])
    with pytest.warns(ObsWarning):
        obs_monitors.run_fleet_monitors(bad, registry=registry)
    with warnings.catch_warnings():
        warnings.simplefilter("error")   # a clean result must not warn
        obs_monitors.run_fleet_monitors(good, registry=registry)
    assert registry.counter("obs.monitor_checks").value == 4.0
    assert registry.counter("obs.monitor_failures").value == 1.0


def test_hops_monotone_monitor_rejects_time_travel():
    rec = type("C", (), {"client": 3, "dispatch_round": 5,
                         "hops": ((0, 4),), "commit_round": 6,
                         "staleness": 1})()
    res = obs_monitors.check_hops_monotone([rec])   # hop before dispatch
    assert not res.ok and res.detail["n_violations"] == 1
    ok_rec = type("C", (), {"client": 3, "dispatch_round": 5,
                            "hops": ((0, 5),), "commit_round": 6,
                            "staleness": 1})()
    assert obs_monitors.check_hops_monotone([ok_rec]).ok


# ----------------------------------------------------------------------
# validation + provenance
# ----------------------------------------------------------------------

def test_validate_rejects_malformed_artifacts(tmp_path):
    assert obs_validate.validate_trace({"traceEvents": [
        {"ph": "Z", "pid": 1, "name": "x"}]}) != []
    assert obs_validate.validate_trace({"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 0, "name": "x", "ts": -1.0,
         "dur": 1.0}]}) != []
    assert obs_validate.validate_metrics(
        {"ts": 0.0, "metrics": {"m": {"kind": "dial", "value": 1}}}) != []
    bad = os.path.join(tmp_path, "bad.json")
    with open(bad, "w") as f:
        json.dump({"traceEvents": []}, f)
    assert obs_validate.main([bad]) == 1
    assert obs_validate.main([]) == 2


def test_provenance_collects_required_keys():
    p = obs_provenance.collect(cwd=REPO)
    assert {"git_sha", "backend", "jax_version",
            "hostname", "platform", "python"} <= set(p)
    assert p["jax_version"] == jax.__version__
    assert p["backend"] == jax.default_backend()
    assert isinstance(p["git_sha"], str) and len(p["git_sha"]) == 40


# ----------------------------------------------------------------------
# traced smokes: the §13 reconciliation acceptance
# ----------------------------------------------------------------------

def test_paged_engine_empty_latency_summary_has_none_fields():
    """Regression: latency_summary on an engine with no completed
    requests used to drop keys / crash np.percentile on []. All five
    keys must be present with None values."""
    from repro.models import Model, get_smoke_config
    from repro.serving import PagedEngine

    cfg = get_smoke_config("granite-3-2b")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    eng = PagedEngine(model, params, batch_size=2, max_seq_len=32,
                      page_size=8)
    summ = eng.latency_summary()
    assert summ == {"requests": 0, "latency_p50": None,
                    "latency_p95": None, "ttft_p50": None,
                    "ttft_p95": None}
    m = eng.metrics()          # and metrics() carries them through
    assert m["latency_p50"] is None and m["ttft_p95"] is None


def test_traced_serve_smoke_reconciles_and_validates(registry, tmp_path):
    """A traced PagedEngine run: serve.pass spans + the pool counter in
    the trace, serving.decode_tokens published into the registry equal
    to the engine's own ledger, pool-conservation monitor clean."""
    from repro.models import Model, get_smoke_config
    from repro.serving import PagedEngine, Request

    cfg = get_smoke_config("granite-3-2b")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    tracer = obs_trace.configure()
    try:
        eng = PagedEngine(model, params, batch_size=2, max_seq_len=32,
                          page_size=8)
        rng = np.random.default_rng(0)
        with warnings.catch_warnings():
            warnings.simplefilter("error", ObsWarning)   # monitors clean
            eng.run([Request(uid=i,
                             prompt=rng.integers(
                                 1, cfg.vocab_size, 4).tolist(),
                             max_new_tokens=4) for i in range(3)])
    finally:
        obs_trace.uninstall()
    names = {e["name"] for e in tracer.events}
    assert {"serve.run", "serve.pass", "serve.admit",
            "pool.pages_live"} <= names
    # the registry mirrors the engine's ledgers exactly
    m = eng.metrics()
    assert registry.gauge("serving.decode_tokens").value == \
        float(m["decode_tokens"]) > 0
    assert registry.gauge("serving.clock").value == float(m["clock"])
    assert registry.gauge("pool.utilization").value == \
        pytest.approx(m["pool_utilization"])
    assert registry.counter("obs.monitor_checks").value >= 1.0
    assert registry.counter("obs.monitor_failures").value == 0.0

    path = os.path.join(tmp_path, "serve.trace.json")
    tracer.export_chrome(path)
    kind, errors = obs_validate.validate_file(path)
    assert (kind, errors) == ("trace", [])


def test_traced_fleet_smoke_reconciles_ledgers(registry, tmp_path):
    """The §13 acceptance for the fleet: a traced hierarchical run's
    ``fleet.tier_bits`` gauge equals BOTH the result's tier_bits sum
    and bits_cum[-1] exactly, the monitors pass, and the trace (with
    its virtual-clock twin track) validates."""
    from repro.core import (LogisticSigmoidProblem, RandK, SNice,
                            make_synthetic_classification)
    from repro.core.dasha_pp import DashaPPConfig
    from repro.fl import (ConstantLatency, DenseProblemWorkload,
                          FleetConfig, HierarchicalFleet, TierConfig)

    n, d = 6, 16
    feats, y = make_synthetic_classification(jax.random.key(0),
                                             n_nodes=n, m_per_node=5, d=d)
    prob = LogisticSigmoidProblem(feats, y)
    wl = DenseProblemWorkload(
        prob, RandK(k=4), SNice(n=n, s=3),
        DashaPPConfig("gradient", gamma=0.02, a=0.1, b=0.3, p_page=0.4,
                      batch_size=2))
    fleet = HierarchicalFleet(wl, FleetConfig(tiers=(TierConfig(
        aggregators=2),)), ConstantLatency(compute_s=1.0))
    tracer = obs_trace.configure()
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("error", ObsWarning)
            fs, res = fleet.run(jax.random.key(7), jnp.zeros(d), 4)
    finally:
        obs_trace.uninstall()

    tier_total = float(np.sum(np.asarray(res.tier_bits)))
    assert registry.gauge("fleet.tier_bits").value == tier_total \
        == float(res.bits_cum[-1]) > 0
    assert registry.gauge("fleet.committed").value == \
        float(res.committed.sum())
    assert registry.histogram("fleet.staleness").count == \
        sum(res.staleness_hist.values())
    assert registry.counter("obs.monitor_failures").value == 0.0

    names = {e["name"] for e in tracer.events}
    assert {"fleet.dispatch", "fleet.flush", "fleet.commit",
            "fleet.bits_cum"} <= names
    # the virtual clock was published: twin events on pid 2
    assert {e["pid"] for e in tracer.events} == {obs_trace.WALL_PID,
                                                 obs_trace.VIRTUAL_PID}
    path = os.path.join(tmp_path, "fleet.trace.json")
    tracer.export_chrome(path)
    kind, errors = obs_validate.validate_file(path)
    assert (kind, errors) == ("trace", [])


@pytest.mark.slow
def test_traced_train_smoke_reconciles_bits_ledger():
    """The §13 acceptance for the trainer: with log_every=1 the
    ``train.bits_sent`` gauge equals the sum of the per-step jsonl
    ``bits_sent`` fields exactly, and the trace validates.  Subprocess
    + host mesh, same pattern as tests/test_training_resume.py."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import json, os, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import make_mesh, use_mesh
        from repro.models import Model, get_smoke_config
        from repro.core.sharded import ShardedDashaConfig
        from repro.obs import metrics as obs_metrics
        from repro.obs import trace as obs_trace
        from repro.obs.validate import validate_file
        from repro.training.loop import train
        from repro.training.metrics import MetricsLogger
        from repro.training.trainer import Trainer, TrainerConfig
        from repro.training.optim import adamw_server

        mesh = make_mesh((2, 2), ('data', 'model'))
        cfg = get_smoke_config('granite-3-2b').with_overrides(vocab_size=64)
        model = Model(cfg)
        dcfg = ShardedDashaConfig(gamma=0.0, a=0.02, b=0.9, p_a=0.5,
                                  sampler='independent',
                                  compression_ratio=0.1, block_size=64,
                                  data_axes=('data',), variant='gradient')
        tr = Trainer(model, mesh, TrainerConfig(
            dasha=dcfg, server=adamw_server(lr=3e-3, warmup=5)))
        toks = jnp.tile(jnp.arange(32) % 7, (2, 2, 1)).astype(jnp.int32)
        def fixed():
            while True:
                yield {'tokens': toks}
        out = tempfile.mkdtemp()
        obs_trace.configure()
        with use_mesh(mesh):
            train(tr, tr.init(jax.random.key(0)), fixed(), num_steps=4,
                  log_every=1, seed=3,
                  logger=MetricsLogger(out_dir=out, print_every=1000))
        tracer = obs_trace.uninstall()
        tpath = os.path.join(out, 'train.trace.json')
        tracer.export_chrome(tpath)
        kind, errors = validate_file(tpath)
        assert (kind, errors) == ('trace', []), errors
        assert sum(1 for e in tracer.events
                   if e['name'] == 'train.step') == 4
        with open(os.path.join(out, 'train.jsonl')) as f:
            recs = [json.loads(line) for line in f]
        assert len(recs) == 4
        jsonl_bits = sum(r['bits_sent'] for r in recs)
        gauge = obs_metrics.get_registry().gauge('train.bits_sent').value
        assert gauge == jsonl_bits > 0, (gauge, jsonl_bits)
        oracle = obs_metrics.get_registry().gauge('train.oracle_calls')
        assert oracle.value == sum(r['participants'] for r in recs)
        print('RECONCILED', gauge)
    """)
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, timeout=520,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "RECONCILED" in out.stdout
