"""Gang-scheduled async cohorts for the sharded LM trainer
(repro/fl/cohorts.py, DESIGN.md §10): trainer-scale sync-limit parity
(pallas on/off), flight-buffered cohorts beating the barrier in virtual
wall-clock, and replay determinism of the Poisson availability process
and delay-adaptive staleness weights.

These need >1 CPU device, so they run in a SUBPROCESS that sets
XLA_FLAGS before importing jax (same pattern as tests/test_sharded.py)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


COMMON = """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.models import Model, get_smoke_config
from repro.core.sharded import ShardedDashaConfig
from repro.training.trainer import Trainer, TrainerConfig
from repro.training.loop import train
from repro.training.optim import paper_server
from repro.fl import (CohortConfig, CohortScheduler, ConstantLatency,
                      LognormalLatency, PoissonAvailability)

mesh = make_mesh((4, 2), ('data', 'model'))
cfg = get_smoke_config('granite-3-2b').with_overrides(vocab_size=64)
model = Model(cfg)
toks = jnp.tile(jnp.arange(32) % 7, (4, 2, 1)).astype(jnp.int32)
batch = {'tokens': toks}

def fixed():
    while True:
        yield batch

def make_trainer(variant, use_pallas=False):
    dcfg = ShardedDashaConfig(gamma=1e-2, a=0.05, b=0.5, p_a=0.5,
                              sampler='independent', compression_ratio=0.1,
                              block_size=64, data_axes=('data',),
                              variant=variant, use_pallas=use_pallas)
    return Trainer(model, mesh, TrainerConfig(dasha=dcfg,
                                              server=paper_server(1e-2)))
"""


@pytest.mark.slow
@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["jnp", "pallas"])
def test_sync_limit_parity_trainer_scale(use_pallas):
    """The §9 parity contract at trainer scale: zero latency jitter +
    the barrier buffer reproduce the synchronous train() trajectory
    allclose (params, g, g_i, h_i) for the mvr and gradient variants —
    the gang-scheduled runtime is an anchored generalization of the
    SPMD trainer, not a fork."""
    out = run_sub(COMMON + f"""
for variant in ('mvr', 'gradient'):
    tr = make_trainer(variant, use_pallas={use_pallas})
    with use_mesh(mesh):
        st_sync = train(tr, tr.init(jax.random.key(0)), fixed(),
                        num_steps=4, log_every=100, seed=3)
        tr2 = make_trainer(variant, use_pallas={use_pallas})
        sched = CohortScheduler(tr2, ConstantLatency(compute_s=1.0),
                                CohortConfig(buffer_cohorts=None, seed=3))
        st_async, res = sched.run(tr2.init(jax.random.key(0)), fixed(), 4)
    pairs = [('params', st_sync.params, st_async.params),
             ('g', st_sync.dasha.g, st_async.dasha.g),
             ('g_i', st_sync.dasha.g_i, st_async.dasha.g_i),
             ('h_i', st_sync.dasha.h_i, st_async.dasha.h_i)]
    for name, sa, sb in pairs:
        for a, b in zip(jax.tree.leaves(sa), jax.tree.leaves(sb)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-6,
                                       err_msg=variant + '/' + name)
    assert set(res.staleness_hist) <= {{0}}, res.staleness_hist
    assert res.skipped_busy.sum() == 0
    print(variant, 'OK', res.staleness_hist)
print('OK')
""")
    assert "OK" in out


@pytest.mark.slow
def test_buffered_cohorts_beat_barrier_and_replay_determinism():
    """(1) Under lognormal heterogeneity the flight-buffered scheduler
    beats the barrier in virtual wall-clock and pays real staleness;
    (2) with the Poisson availability process AND delay-adaptive
    weights on top, the same seed replays the identical event log and
    final iterate; (3) conservation: every dispatched cohort commits or
    is discarded."""
    out = run_sub(COMMON + """
lat = LognormalLatency(compute_s=1.0, sigma=1.2, client_sigma=1.2, seed=3)

def run(K, avail=None, policy='power', rounds=12):
    tr = make_trainer('mvr')
    with use_mesh(mesh):
        sched = CohortScheduler(
            tr, lat, CohortConfig(buffer_cohorts=K, seed=3,
                                  staleness_policy=policy),
            availability=avail)
        return sched.run(tr.init(jax.random.key(0)), fixed(), rounds)

_, res_bar = run(None)
_, res_buf = run(3)
assert res_buf.total_time < res_bar.total_time, (
    res_buf.total_time, res_bar.total_time)
assert any(s > 0 for s in res_buf.staleness_hist)
assert all(s == 0 for s in res_bar.staleness_hist)
for res in (res_bar, res_buf):
    dispatched = int((res.participants > 0).sum())
    assert int(res.committed.sum()) + res.discarded_stale == dispatched
    assert np.all(np.isfinite(res.loss))
print('speedup', res_bar.total_time / res_buf.total_time)

av = lambda: PoissonAvailability(rate=0.4, off_mean=4.0, seed=5)
s1, r1 = run(2, av(), 'adaptive', rounds=15)
s2, r2 = run(2, av(), 'adaptive', rounds=15)
assert r1.event_log == r2.event_log and len(r1.event_log) > 0
for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-7)
assert int(r1.skipped_offline.sum()) > 0
print('OK')
""")
    assert "OK" in out


@pytest.mark.slow
def test_mid_flight_dropout_and_rejoin():
    """Mid-flight dropout at trainer scale: (0) the reliable-transport
    default never routes through the excision path; (1) partial dropout
    excises members, schedules rejoins, and replays deterministically;
    (2) total dropout (every member lost) leaks NOTHING into the server
    estimators, never freezes the clock, and re-dispatches rejoined
    clients in later rounds with fresh round keys."""
    out = run_sub(COMMON + """
# (0) reliable default: no drops, no rejoins, excision never engages
tr = make_trainer('gradient')
with use_mesh(mesh):
    sched = CohortScheduler(tr, ConstantLatency(compute_s=1.0),
                            CohortConfig(buffer_cohorts=None, seed=3))
    _, res0 = sched.run(tr.init(jax.random.key(0)), fixed(), 4)
assert res0.dropped_members == 0
assert not any(e[2] == 'rejoin' for e in res0.event_log)

# (1) partial dropout: excision + rejoin + replay determinism
lat = LognormalLatency(compute_s=1.0, sigma=0.8, client_sigma=0.8,
                       dropout=0.5, seed=7)
def run():
    tr = make_trainer('gradient')
    with use_mesh(mesh):
        sched = CohortScheduler(tr, lat,
                                CohortConfig(buffer_cohorts=2, seed=3))
        return sched.run(tr.init(jax.random.key(0)), fixed(), 10)
s1, r1 = run()
s2, r2 = run()
assert r1.dropped_members > 0
assert int(r1.committed.sum()) > 0
assert any(e[2] == 'rejoin' for e in r1.event_log)
assert int(r1.committed.sum()) + r1.discarded_stale \\
    <= int((r1.participants > 0).sum())
assert r1.event_log == r2.event_log and len(r1.event_log) > 0
for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
assert np.all(np.isfinite(r1.loss))
print('partial OK', r1.dropped_members)

# (2) total dropout: no estimator leak, no frozen clock, rejoins
# re-enter later cohorts
tr = make_trainer('gradient')
with use_mesh(mesh):
    st0 = tr.init(jax.random.key(0))
    g0 = jax.tree.map(np.asarray, st0.dasha.g)
    sched = CohortScheduler(
        tr, ConstantLatency(compute_s=1.0, dropout=1.0, rejoin_s=2.0),
        CohortConfig(buffer_cohorts=2, seed=3))
    st, res = sched.run(st0, fixed(), 8)
for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(st.dasha.g)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
assert int(res.committed.sum()) == 0
assert res.dropped_members == int(res.participants.sum()) > 0
assert res.total_time > 0.0
assert int((res.participants > 0).sum()) > 1
print('OK')
""")
    assert "OK" in out
