"""End-to-end behaviour tests for the paper's system: baselines run,
degradation ordering holds, substrates (data/checkpoint/serving) work."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Frecon, FreconConfig, Marina, MarinaConfig, RandK,
                        SNice, dasha, dasha_pp, theory)


def _constants(prob):
    L, L_hat, L_max, L_sigma = prob.smoothness()
    return theory.ProblemConstants(L=L, L_hat=L_hat, L_max=L_max,
                                   L_sigma=L_sigma, n=prob.n, m=prob.m,
                                   d=prob.d)


def test_pp_degradation_bounded_by_inverse_pa(small_problem):
    """Paper Fig. 1 claim at test scale: rounds(PP)/rounds(full) <= ~1/p_a
    with theory parameters and a shared (tuned) stepsize."""
    prob = small_problem
    c = _constants(prob)
    comp = RandK(k=max(1, prob.d // 8))
    omega = comp.omega(prob.d)
    x0 = jnp.zeros(prob.d)
    gamma = theory.dasha_gradient(c, omega).gamma * 4

    runs = {}
    for s in (prob.n, 3):
        samp = SNice(n=prob.n, s=s)
        hp = theory.dasha_pp_gradient(c, omega, samp.p_a, samp.p_aa)
        alg = dasha_pp(prob, comp, samp, gamma=gamma, a=hp.a, b=hp.b)
        _, mets = jax.jit(lambda k, a=alg: a.run(k, x0, 2500))(
            jax.random.key(3))
        runs[s] = np.asarray(mets.grad_norm_sq)
    eps = runs[prob.n][300]
    r_full = int(np.argmax(runs[prob.n] <= eps))
    hit = np.nonzero(runs[3] <= eps)[0]
    assert hit.size, "PP run never reached the full-participation level"
    ratio = hit[0] / max(r_full, 1)
    inv_pa = prob.n / 3
    assert ratio <= 1.6 * inv_pa, (ratio, inv_pa)


def test_marina_and_frecon_run(small_problem):
    prob = small_problem
    comp = RandK(k=4)
    samp = SNice(n=prob.n, s=4)
    x0 = jnp.zeros(prob.d)
    m = Marina(prob, comp, samp, MarinaConfig(gamma=0.02, p_sync=0.2))
    _, mm = jax.jit(lambda k: m.run(k, x0, 300))(jax.random.key(0))
    assert np.isfinite(np.asarray(mm.grad_norm_sq)).all()
    assert mm.grad_norm_sq[-1] < mm.grad_norm_sq[0]
    f = Frecon(prob, comp, samp, FreconConfig(gamma=0.02, batch_size=2))
    _, mf = jax.jit(lambda k: f.run(k, x0, 300))(jax.random.key(1))
    assert np.isfinite(np.asarray(mf.loss)).all()


def test_data_pipeline_node_major_and_heterogeneous():
    from repro.data.synthetic import DataConfig, make_batch, token_batches
    from repro.models import get_smoke_config
    cfg = get_smoke_config("granite-3-2b")
    dc = DataConfig(seq_len=32, global_batch=8, num_nodes=4,
                    vocab_size=cfg.vocab_size)
    it = token_batches(dc)
    b1, b2 = next(it), next(it)
    assert b1["tokens"].shape == (4, 2, 32)
    assert not np.array_equal(b1["tokens"], b2["tokens"])
    # heterogeneity: node unigram histograms differ
    h = [np.bincount(b1["tokens"][i].ravel(), minlength=dc.vocab_size)
         for i in range(4)]
    assert not np.array_equal(h[0], h[1])
    # modality batches
    vb = make_batch(get_smoke_config("paligemma-3b"), dc, dtype="float32")
    assert "embeds" in vb and vb["embeds"].shape[2] == 8
    ab = make_batch(get_smoke_config("hubert-xlarge"), dc, dtype="float32")
    assert ab["embeds"].shape == (4, 2, 32, 128)


def test_checkpoint_roundtrip(tmp_path):
    from repro.training.checkpoints import (latest_step, restore_checkpoint,
                                            save_checkpoint)
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "nested": {"b": jnp.ones((4,), jnp.int32)},
             "t": (jnp.zeros(()), jnp.full((2,), 7.0))}
    save_checkpoint(str(tmp_path), state, step=3)
    assert latest_step(str(tmp_path)) == 3
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    back = restore_checkpoint(str(tmp_path), like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_server_generates():
    from repro.models import Model, get_smoke_config
    from repro.serving.decode import DecodeServer, Request
    cfg = get_smoke_config("granite-3-2b")
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    srv = DecodeServer(model, params, batch_size=2, max_seq_len=32)
    reqs = [Request(uid=i, prompt=[1, 2, 3], max_new_tokens=4)
            for i in range(3)]
    done = srv.run(reqs)
    for r in done:
        assert len(r.generated) == 4
        assert all(0 <= t < cfg.padded_vocab for t in r.generated)


def test_registry_pairs():
    from repro.models import (ARCH_IDS, INPUT_SHAPES, get_config,
                              pair_supported)
    statuses = {}
    for a in ARCH_IDS:
        for s in INPUT_SHAPES.values():
            cfg = get_config(a)
            if s.name == "long_500k":
                cfg = cfg.for_long_context()
            ok, why = pair_supported(cfg, s)
            statuses[(a, s.name)] = ok
    # exactly the 2 documented encoder-decode skips
    skipped = [k for k, v in statuses.items() if not v]
    assert sorted(skipped) == [("hubert-xlarge", "decode_32k"),
                               ("hubert-xlarge", "long_500k")]
    assert len(statuses) == 40
