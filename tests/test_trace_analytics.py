"""PR 10 trace analytics (repro/obs/analyze/, DESIGN.md §15): causal
flow links through the fleet runtimes, per-round critical-path
attribution priced by the latency models, exact trace-vs-ledger bit
reconciliation, span-tree rollups, bench-trajectory drift detection,
and the tracer's bounded-memory drop policy.

The acceptance anchor: on a ZERO-JITTER BARRIER fleet run the critical
path of every committed round collapses to the slowest participating
client's compute + uplink chain — all wait segments are zero and the
decomposition telescopes exactly."""
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs import validate as obs_validate
from repro.obs.analyze import (analyze_critical_path, analyze_trajectory,
                               reconcile_bits, span_rollup)
from repro.obs.analyze.trajectory import load_trajectory_entries
from repro.obs.metrics import Registry
from repro.obs.monitors import ObsWarning

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture
def registry():
    old = obs_metrics.get_registry()
    reg = obs_metrics.set_registry(Registry())
    yield reg
    obs_metrics.set_registry(old)


def _run_barrier_fleet(registry, rounds=4):
    """A fully deterministic (zero-jitter) barrier fleet: 8 clients in
    2 edges, persistent per-client speed spread, no per-dispatch
    randomness, identical edge->root links."""
    from repro.core import RandK
    from repro.core.participation import EdgeSNice
    from repro.fl import (ConstantLatency, FleetConfig, HierarchicalFleet,
                          LognormalLatency, StreamedGradientWorkload,
                          TierConfig)

    n, d = 8, 16
    samp = EdgeSNice(bounds=(0, 4, 8), s=4)  # every client, every round
    wl = StreamedGradientWorkload(sampler=samp, d=d, compressor=RandK(k=4),
                                  gamma=0.02, a=0.1, b=0.3,
                                  m_per_client=2, data_seed=0)
    # sigma=0: per-dispatch jitter multiplier is exactly 1, leaving only
    # the persistent per-client lognormal spread -> deterministic,
    # heterogeneous, round-independent job pricing
    lat = LognormalLatency(compute_s=0.5, sigma=0.0, client_sigma=0.8,
                           bandwidth_bps=2e4, seed=3)
    link = ConstantLatency(compute_s=0.05)   # same for both edges
    fcfg = FleetConfig(tiers=(TierConfig(aggregators=2, latency=link),),
                       buffer_size=None)     # barrier root
    fleet = HierarchicalFleet(wl, fcfg, lat)
    tracer = obs_trace.configure()
    try:
        with warnings.catch_warnings():
            warnings.simplefilter("error", ObsWarning)
            fs, res = fleet.run(jax.random.key(1), np.zeros(d, np.float32),
                                rounds)
    finally:
        obs_trace.uninstall()
    return tracer.to_chrome(), res, wl, lat


# ----------------------------------------------------------------------
# critical path: the zero-jitter barrier acceptance
# ----------------------------------------------------------------------

def test_zero_jitter_barrier_round_is_bound_by_slowest_client(registry):
    """On a zero-jitter barrier run each round's critical path is
    entirely the slowest participating client's compute + uplink chain:
    every wait segment is zero, the segment decomposition telescopes to
    the commit-minus-dispatch total, and the bounding client is the
    argmax of the latency model's own per-client pricing."""
    rounds = 4
    doc, res, wl, lat = _run_barrier_fleet(registry, rounds=rounds)
    cp = analyze_critical_path(doc)
    assert cp is not None and len(cp.rounds) == rounds

    # participants per dispatch round, straight from the flow graph
    contribs = [e for e in doc["traceEvents"]
                if e.get("ph") == "s" and e["name"] == "fleet.contrib"
                and e["pid"] == obs_trace.VIRTUAL_PID]
    by_round = {}
    for c in contribs:
        by_round.setdefault(c["args"]["round"], []).append(c["args"])

    for rp in cp.rounds:
        # 1) all wait segments are zero (barrier + zero jitter)
        assert rp.buffer_wait_us == pytest.approx(0.0, abs=1e-6)
        assert rp.forced_flush_us == pytest.approx(0.0, abs=1e-6)
        assert rp.root_wait_us == pytest.approx(0.0, abs=1e-6)
        # 2) the decomposition telescopes exactly (fp rounding only)
        assert abs(rp.residual_us()) < 1e-6 * max(rp.total_us, 1.0)
        assert rp.compute_us + rp.network_us == \
            pytest.approx(rp.total_us, rel=1e-9)
        # 3) the bound client is the latency model's own slowest chain,
        #    recomputed independently of the trace
        parts = by_round[rp.bound_dispatch_round]
        assert len(parts) == 8     # s=4 per edge x 2 edges
        expect = max(
            parts, key=lambda a: (lambda t: t.compute_s + t.network_s)(
                lat.job(a["client"], rp.bound_dispatch_round,
                        wl.wire_bits)))
        assert rp.bound_client == expect["client"]
        # chain = client contribution -> edge flush message
        assert len(rp.chain) == 2

    # links priced identically for both edges: the 0.05 s edge->root leg
    # is on every round's path
    for rp in cp.rounds:
        t = lat.job(rp.bound_client, rp.bound_dispatch_round, wl.wire_bits)
        assert rp.compute_us == pytest.approx((t.compute_s + 0.05) * 1e6)
        assert rp.network_us == pytest.approx(t.network_s * 1e6)


def test_barrier_fleet_bits_reconcile_exactly_with_ledger(registry):
    """Summing ``bits`` over the trace's contrib flow-starts (hop 0)
    and flush spans (hop k+1) reproduces the ``fleet.tier_bits.hop<k>``
    gauges EXACTLY (atol=0): trace and ledger are two exports of the
    same accounting."""
    doc, res, wl, lat = _run_barrier_fleet(registry)
    cp = analyze_critical_path(doc)
    rec = reconcile_bits(cp, registry.snapshot(), atol=0.0)
    assert rec["ledger_found"] and rec["ledger_ok"]
    assert all(h["match"] for h in rec["hops"].values())
    assert set(cp.bits_by_hop) == {0, 1}
    assert cp.bits_by_hop[0] == float(
        registry.gauge("fleet.tier_bits.hop0").value)
    assert sum(cp.bits_by_hop.values()) == float(
        registry.gauge("fleet.tier_bits").value) == float(res.bits_cum[-1])


def test_critical_path_returns_none_without_flow_graph():
    doc = {"traceEvents": [{"ph": "X", "pid": obs_trace.WALL_PID,
                            "tid": 1, "name": "serve.step", "ts": 0.0,
                            "dur": 5.0}]}
    assert analyze_critical_path(doc) is None


# ----------------------------------------------------------------------
# flow events: emission + validator round-trip
# ----------------------------------------------------------------------

def test_flow_events_roundtrip_through_validator(tmp_path):
    t = obs_trace.configure()
    try:
        with obs_trace.span("dispatch", track="fleet"):
            obs_trace.flow_start("fleet.contrib", 7, track="fleet",
                                 client=3, bits=64.0)
        with obs_trace.span("flush", track="fleet"):
            obs_trace.flow_step("fleet.contrib", 7, track="fleet")
        with obs_trace.span("commit", track="fleet"):
            obs_trace.flow_end("fleet.contrib", 7, track="fleet")
    finally:
        obs_trace.uninstall()
    phases = [e["ph"] for e in t.events if e.get("cat") == "flow"]
    assert phases == ["s", "t", "f"]
    ends = [e for e in t.events if e.get("ph") == "f"]
    assert ends[0]["bp"] == "e" and ends[0]["id"] == 7
    path = os.path.join(tmp_path, "flow.trace.json")
    t.export_chrome(path)
    kind, errors = obs_validate.validate_file(path)
    assert (kind, errors) == ("trace", [])


def test_validator_rejects_malformed_flow_events():
    base = {"ph": "s", "pid": 1, "tid": 1, "name": "f", "cat": "flow",
            "ts": 0.0}
    assert obs_validate.validate_trace(
        {"traceEvents": [dict(base, id=1)]}) == []
    # flow events need an integer id
    assert obs_validate.validate_trace({"traceEvents": [base]})
    assert obs_validate.validate_trace(
        {"traceEvents": [dict(base, id="seven")]})
    # binding point on "f" must be "e" (or absent)
    bad = dict(base, ph="f", id=1, bp="x")
    assert obs_validate.validate_trace({"traceEvents": [bad]})


# ----------------------------------------------------------------------
# dual-clock export edge cases (never published / cleared mid-run)
# ----------------------------------------------------------------------

def test_never_published_virtual_clock_exports_wall_only(tmp_path):
    t = obs_trace.configure()
    try:
        with obs_trace.span("fleet.dispatch", track="fleet"):
            obs_trace.flow_start("fleet.contrib", 1, track="fleet")
        obs_trace.instant("fleet.flush", track="fleet")
    finally:
        obs_trace.uninstall()
    doc = t.to_chrome()
    data = [e for e in doc["traceEvents"] if e.get("ph") != "M"]
    assert data and {e["pid"] for e in data} == {obs_trace.WALL_PID}
    path = os.path.join(tmp_path, "wall.trace.json")
    t.export_chrome(path)
    assert obs_validate.validate_file(path) == ("trace", [])


def test_virtual_clock_cleared_mid_run_truncates_cleanly(tmp_path):
    """A runtime that publishes the virtual clock then finishes (run 1)
    must not leak virtual-clock twins into a later untraced-virtual
    phase (run 2) — the exact bleed ``clear_virtual_time`` exists to
    prevent.  Spans OPEN at clear time lose their twin (no mixed-clock
    span: a twin priced on a clock that died mid-span would lie)."""
    t = obs_trace.configure()
    try:
        obs_trace.set_virtual_time(1.0)
        with obs_trace.span("run1.step", track="sim"):
            pass
        # span open across the clear: no virtual twin may be emitted
        with obs_trace.span("run1.tail", track="sim"):
            obs_trace.clear_virtual_time()
        with obs_trace.span("run2.step", track="sim"):
            pass
    finally:
        obs_trace.uninstall()
    virt = [e for e in t.events if e["pid"] == obs_trace.VIRTUAL_PID]
    assert {e["name"] for e in virt} == {"run1.step"}
    path = os.path.join(tmp_path, "cleared.trace.json")
    t.export_chrome(path)
    assert obs_validate.validate_file(path) == ("trace", [])


# ----------------------------------------------------------------------
# tracer memory bound + drop counter
# ----------------------------------------------------------------------

def test_tracer_drops_newest_beyond_cap_and_counts(registry, tmp_path):
    t = obs_trace.configure(max_events=5)
    try:
        for i in range(9):
            obs_trace.instant(f"e{i}", track="x")
    finally:
        obs_trace.uninstall()
    assert len(t.events) == 5 and t.dropped == 4
    # retained prefix is the OLDEST events (drop-newest keeps the trace
    # causally consistent: no arrows into the void)
    assert [e["name"] for e in t.events] == [f"e{i}" for i in range(5)]
    assert registry.counter("obs.dropped_events").value == 4.0
    doc = t.to_chrome()
    assert doc["metadata"]["dropped_events"] == 4
    path = os.path.join(tmp_path, "capped.trace.json")
    t.export_chrome(path)
    assert obs_validate.validate_file(path) == ("trace", [])


# ----------------------------------------------------------------------
# span rollup
# ----------------------------------------------------------------------

def test_span_rollup_self_vs_child_time():
    doc = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 1, "name": "outer", "ts": 0.0,
         "dur": 100.0},
        {"ph": "X", "pid": 1, "tid": 1, "name": "inner", "ts": 10.0,
         "dur": 30.0},
        {"ph": "X", "pid": 1, "tid": 1, "name": "inner", "ts": 50.0,
         "dur": 20.0},
        # other lane: must not nest under the tid-1 stack
        {"ph": "X", "pid": 1, "tid": 2, "name": "other", "ts": 0.0,
         "dur": 7.0},
    ]}
    rows = {r["name"]: r for r in span_rollup(doc)}
    assert rows["outer"]["count"] == 1
    assert rows["outer"]["total_us"] == pytest.approx(100.0)
    assert rows["outer"]["child_us"] == pytest.approx(50.0)
    assert rows["outer"]["self_us"] == pytest.approx(50.0)
    assert rows["inner"]["count"] == 2
    assert rows["inner"]["self_us"] == pytest.approx(50.0)
    assert rows["other"]["self_us"] == pytest.approx(7.0)


# ----------------------------------------------------------------------
# trajectory analyzer
# ----------------------------------------------------------------------

def _serving_entry(ts, tok_s):
    return {"ts": ts, "mode": "smoke", "backend": "cpu", "cells": [],
            "decode": [{"batch": 8, "max_seq": 64,
                        "paged_decode_tok_s": float(tok_s)}]}


def test_trajectory_flags_injected_2x_decode_slowdown():
    entries = [_serving_entry(f"2026-08-0{i+1}T00:00:00", v)
               for i, v in enumerate([6000.0, 6600.0, 5700.0])]
    assert analyze_trajectory(entries) == []     # ±10% jitter: quiet
    entries.append(_serving_entry("2026-08-04T00:00:00", 3000.0))
    findings = analyze_trajectory(entries)
    assert [f.kind for f in findings] == ["regression"]
    f = findings[0]
    assert f.metric == "paged_decode_tok_s" and f.detector == "drift"
    assert f.ratio == pytest.approx(0.5, rel=0.01)


def test_trajectory_reports_improvement_not_regression():
    entries = [_serving_entry(f"2026-08-0{i+1}T00:00:00", v)
               for i, v in enumerate([6000.0, 6100.0, 5900.0, 12000.0])]
    findings = analyze_trajectory(entries)
    assert [f.kind for f in findings] == ["improvement"]


def test_trajectory_exact_counter_must_not_move():
    def entry(ts, bits):
        return {"ts": ts, "mode": "smoke", "cells": [
            {"n": 64, "total_mbits": float(bits)}]}
    quiet = [entry("a", 14.044), entry("b", 14.044)]
    assert analyze_trajectory(quiet) == []
    moved = quiet + [entry("c", 14.046)]
    findings = analyze_trajectory(moved)
    assert len(findings) == 1 and findings[0].kind == "regression"


def test_trajectory_level_shift_catches_walked_down_baseline():
    """A sustained step that predates the latest run: drift (latest vs
    prior median) stays quiet once the step dominates the median, but
    the level-shift split still finds it."""
    vals = [6000.0, 6100.0, 2900.0, 3000.0, 3100.0, 2950.0]
    entries = [_serving_entry(f"2026-08-0{i+1}T00:00:00", v)
               for i, v in enumerate(vals)]
    findings = analyze_trajectory(entries)
    assert [f.detector for f in findings] == ["level_shift"]
    assert findings[0].kind == "regression"


def test_committed_trajectories_are_quiet():
    """The analyzer must not cry wolf on the repo's own committed bench
    history (serving, fleet, and the converted kernels trajectory)."""
    for rel in ("results/BENCH_serving.json", "results/BENCH_fleet.json",
                "results/bench/kernels.json"):
        path = os.path.join(REPO, rel)
        entries = load_trajectory_entries(path)
        assert entries, rel
        bad = [f for f in analyze_trajectory(entries)
               if f.kind != "improvement"]
        assert bad == [], (rel, [f.as_dict() for f in bad])


def test_legacy_bare_list_absorbed_as_one_entry(tmp_path):
    p = os.path.join(tmp_path, "legacy.json")
    with open(p, "w") as f:
        json.dump([[{"name": "k", "us_unfused": 1.0}],
                   [{"name": "k2", "us_unfused": 2.0}]], f)
    entries = load_trajectory_entries(p)
    assert len(entries) == 1 and entries[0]["mode"] == "legacy"
    assert [c["name"] for c in entries[0]["cells"]] == ["k", "k2"]


# ----------------------------------------------------------------------
# report CLI + schema
# ----------------------------------------------------------------------

def test_report_end_to_end_over_traced_fleet(registry, tmp_path):
    from repro.obs import report as obs_report

    doc, res, wl, lat = _run_barrier_fleet(registry)
    trace_path = os.path.join(tmp_path, "fleet.trace.json")
    with open(trace_path, "w") as f:
        json.dump(doc, f)
    metrics_path = os.path.join(tmp_path, "fleet.metrics.json")
    registry.write_snapshot(metrics_path)
    json_out = os.path.join(tmp_path, "report.json")
    md_out = os.path.join(tmp_path, "report.md")
    rc = obs_report.main(["--trace", trace_path,
                          "--metrics", metrics_path,
                          "--trajectory",
                          os.path.join(REPO, "results/BENCH_fleet.json"),
                          "--json", json_out, "--md", md_out])
    assert rc == 0
    with open(json_out) as f:
        rep = json.load(f)
    assert obs_validate.validate_report(rep) == []
    assert obs_validate.validate_file(json_out) == ("report", [])
    assert rep["summary"]["reconciled"] is True
    assert rep["summary"]["regressions"] == 0
    assert rep["critical_path"]["rounds"]
    with open(md_out) as f:
        md = f.read()
    assert "Critical path" in md and "reconcil" in md.lower()


def test_report_self_test_catches_injected_regression():
    from repro.obs import report as obs_report
    assert obs_report.self_test() == 0
