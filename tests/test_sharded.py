"""SPMD runtime tests on a small host-device mesh.

These need >1 CPU device, so they run in a SUBPROCESS that sets
XLA_FLAGS before importing jax (the main pytest process keeps the
default 1-device view, as required for the smoke tests)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import make_mesh, use_mesh
from repro.core.sharded import (ShardedDasha, ShardedDashaConfig,
                                per_node_value_and_grads)
mesh = make_mesh((4, 2), ('data', 'model'))
def loss_fn(params, batch):
    x, y = batch
    return jnp.mean((x @ params['w'] - y) ** 2)
D = 64
params = {'w': jax.random.normal(jax.random.key(0), (D, 8)) * 0.1}
specs = {'w': P(None, 'model')}
xb = jax.random.normal(jax.random.key(1), (4, 32, D))
yb = xb @ jax.random.normal(jax.random.key(2), (D, 8))
def fit(cfg, steps=250):
    eng = ShardedDasha(mesh, specs, cfg)
    with use_mesh(mesh):
        p = {'w': jax.device_put(params['w'], NamedSharding(mesh, P(None, 'model')))}
        @jax.jit
        def step(params_, state, key):
            pn = eng.server_step(params_, state)
            _, gn = per_node_value_and_grads(loss_fn, pn, (xb, yb))
            _, go = per_node_value_and_grads(loss_fn, params_, (xb, yb))
            st_new, _ = eng.node_update(gn, go, state, key)
            return pn, st_new
        _, g0 = per_node_value_and_grads(loss_fn, p, (xb, yb))
        st = eng.init(g0)
        for i in range(steps):
            p, st = step(p, st, jax.random.key(i))
        l = loss_fn(p, (xb, yb))
    return float(l), jax.device_get(st.g['w'])
"""


@pytest.mark.slow
def test_sharded_dasha_converges_and_modes_agree():
    out = run_sub(COMMON + """
base = dict(gamma=0.02, a=0.5/7, b=1/3, p_a=0.5, sampler='independent',
            block_size=8, data_axes=('data',))
l_sparse, g_sparse = fit(ShardedDashaConfig(compression_ratio=0.25,
                                            aggregation='sparse_allgather', **base))
l_dense, g_dense = fit(ShardedDashaConfig(compression_ratio=0.25,
                                          aggregation='dense_psum', **base))
l_id, _ = fit(ShardedDashaConfig(compression_ratio=None, **base))
assert l_sparse < 8.0, l_sparse        # converging (start ~58, 10x drop)
np.testing.assert_allclose(g_sparse, g_dense, rtol=1e-5, atol=1e-6)
assert l_id < 8.0
print('OK', l_sparse, l_dense, l_id)
""")
    assert "OK" in out


@pytest.mark.slow
def test_sharded_pallas_path_matches_jnp():
    """The fused kernel path must reproduce the jnp trajectory in every
    aggregation mode (sparse wire, dense psum, uncompressed)."""
    out = run_sub(COMMON + """
base = dict(gamma=0.02, a=0.5/7, b=1/3, p_a=0.5, sampler='independent',
            block_size=8, data_axes=('data',))
for extra in (dict(compression_ratio=0.25, aggregation='sparse_allgather'),
              dict(compression_ratio=0.25, aggregation='dense_psum'),
              dict(compression_ratio=None)):
    _, g_jnp = fit(ShardedDashaConfig(use_pallas=False, **base, **extra),
                   steps=40)
    _, g_pal = fit(ShardedDashaConfig(use_pallas=True, **base, **extra),
                   steps=40)
    np.testing.assert_allclose(g_jnp, g_pal, rtol=1e-5, atol=1e-6)
    print('mode ok', extra)
print('OK')
""")
    assert "OK" in out


PARITY = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, use_mesh
from repro.core import variants, BlockRandK, Identity, SNice
from repro.core.dasha_pp import DashaPP, DashaPPConfig
from repro.core.sharded import ShardedDasha, ShardedDashaConfig
from repro.core.problems import (LogisticSigmoidProblem,
                                 make_synthetic_classification,
                                 sample_batch_indices)

n, m, d, B, T = 4, 6, 32, 2, 10
feats, y = make_synthetic_classification(jax.random.key(0), n_nodes=n,
                                         m_per_node=m, d=d)
prob = LogisticSigmoidProblem(feats, y)
mesh = make_mesh((4,), ('data',))
specs = {'w': P()}
RUN = jax.random.key(42)
x0 = jnp.zeros(d)
samp = SNice(n=n, s=2)
gamma, a, b, p_page = 0.05, 0.1, 0.3, 0.4

def ref_run(variant, compressor, pallas):
    cfg = DashaPPConfig(variant, gamma=gamma, a=a, b=b, p_page=p_page,
                        batch_size=B, use_pallas=pallas)
    alg = DashaPP(prob, compressor, samp, cfg)
    st = alg.init(jax.random.key(0), x0)
    step = jax.jit(alg.step)
    for t in range(T):
        st, _ = step(jax.random.fold_in(RUN, t), st)
    return st

def sharded_run(variant, agg, ratio, pallas):
    cfg = ShardedDashaConfig(gamma=gamma, a=a, b=b, p_a=0.5,
                             sampler='s_nice', compression_ratio=ratio,
                             block_size=8, aggregation=agg,
                             data_axes=('data',), variant=variant,
                             p_page=p_page, use_pallas=pallas)
    eng = ShardedDasha(mesh, specs, cfg)

    # One round: the oracle inputs are computed from the SAME problem
    # with the SAME key derivation the reference engine consumes
    # (variants.round_keys contract) — so the trajectories must agree
    # element-wise, not just in distribution.
    @jax.jit
    def round_fn(x, st, key):
        xn = eng.server_step(x, st)
        _, k_oracle, _ = variants.round_keys(key, st.step)
        kw = {}
        if variant == 'mvr':
            idx = sample_batch_indices(k_oracle, n, m, B, replace=True)
            gn = {'w': prob.batch_grad(xn['w'], idx)}
            go = {'w': prob.batch_grad(x['w'], idx)}
        elif variant == 'gradient':
            gn = {'w': prob.grad(xn['w'])}
            go = {'w': prob.grad(x['w'])}
        elif variant == 'page':
            _, k_batch = variants.page_keys(k_oracle)
            idx = sample_batch_indices(k_batch, n, m, B, replace=True)
            gn = {'w': prob.grad(xn['w'])}
            go = {'w': prob.grad(x['w'])}
            kw = dict(mini_new={'w': prob.batch_grad(xn['w'], idx)},
                      mini_old={'w': prob.batch_grad(x['w'], idx)})
        else:
            idx = sample_batch_indices(k_oracle, n, m, B, replace=False)
            gn = {'w': prob.component_grads(xn['w'], idx)}
            go = {'w': prob.component_grads(x['w'], idx)}
            kw = dict(component_idx=idx)
        st2, met = eng.node_update(gn, go, st, key, **kw)
        return xn, st2, met

    with use_mesh(mesh):
        hij0 = None
        if variant == 'finite_mvr':
            all_idx = jnp.broadcast_to(jnp.arange(m)[None, :], (n, m))
            hij0 = {'w': prob.component_grads(x0, all_idx)}
        st = eng.init({'w': prob.grad(x0)}, h_ij0=hij0)
        x = {'w': x0}
        for t in range(T):
            x, st, met = round_fn(x, st, RUN)
    return x['w'], st, met, eng

def check(pallas):
    for variant in ('mvr', 'gradient', 'page', 'finite_mvr'):
        for agg, ratio in (('sparse_allgather', 0.25),
                           ('dense_psum', 0.25),
                           ('sparse_allgather', None)):
            comp = Identity() if ratio is None else \\
                BlockRandK(ratio=ratio, block_size=8)
            st_ref = ref_run(variant, comp, pallas)
            x_sh, st_sh, met, eng = sharded_run(variant, agg, ratio,
                                                pallas)
            for name, a_, b_ in [('x', st_ref.x, x_sh),
                                 ('g', st_ref.g, st_sh.g['w']),
                                 ('h_i', st_ref.h_i, st_sh.h_i['w']),
                                 ('g_i', st_ref.g_i, st_sh.g_i['w'])]:
                np.testing.assert_allclose(
                    np.asarray(a_), np.asarray(b_), rtol=1e-4, atol=1e-5,
                    err_msg=f'{variant}/{agg}/ratio={ratio}/{name}')
            if variant == 'finite_mvr':
                np.testing.assert_allclose(
                    np.asarray(st_ref.h_ij), np.asarray(st_sh.h_ij['w']),
                    rtol=1e-4, atol=1e-5)
            # engine-measured bits match the aggregation-aware accounting
            per_node = eng.uplink_bits_per_round(d) / eng.cfg.p_a
            assert float(met.bits_sent) == \\
                float(met.participants) * per_node, (variant, agg)
            print('parity ok', variant, agg, ratio, flush=True)
"""


@pytest.mark.slow
def test_variant_parity_vs_reference_jnp():
    """Acceptance: ShardedDasha reproduces the reference DashaPP
    trajectory for ALL FOUR variants in every aggregation mode (matched
    keys; page coin and batch randomness consumed identically)."""
    out = run_sub(PARITY + "\ncheck(pallas=False)\nprint('OK')\n",
                  devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_variant_parity_vs_reference_pallas():
    """Same acceptance matrix with the fused Pallas update paths."""
    out = run_sub(PARITY + "\ncheck(pallas=True)\nprint('OK')\n",
                  devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_bits_accounting_on_model_axis_mesh():
    """bits_sent must count each node's message ONCE even when leaves
    are replicated across the model axis (regression: a psum over all
    mesh axes tallied replicated leaves once per model shard)."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, use_mesh
from repro.core.sharded import ShardedDasha, ShardedDashaConfig

mesh = make_mesh((2, 2), ('data', 'model'))
dw, dv = 64, 128
# 'w' replicated over model; 'v' sharded over model.
specs = {'w': P(), 'v': P(None, 'model')}
g0 = {'w': jnp.ones((2, dw)), 'v': jnp.ones((2, dv // 8, 8))}

def bits(ratio, aggregation):
    cfg = ShardedDashaConfig(gamma=0.1, a=0.1, b=0.3, p_a=1.0,
                             sampler='full', compression_ratio=ratio,
                             block_size=8, aggregation=aggregation,
                             data_axes=('data',))
    eng = ShardedDasha(mesh, specs, cfg)
    with use_mesh(mesh):
        st = eng.init(g0)
        st, met = eng.node_update(g0, g0, st, jax.random.key(0))
    return float(met.participants), float(met.bits_sent)

# uncompressed: 2 nodes x (dw + dv) x 32 bits — NOT x2 for the model axis
parts, b = bits(None, 'sparse_allgather')
assert parts == 2.0
assert b == 2 * (dw + dv) * 32.0, b
# dense_psum moves dense bits too
_, b = bits(0.25, 'dense_psum')
assert b == 2 * (dw + dv) * 32.0, b
# sparse: per model shard, kb = ceil(.25 * nb) blocks of (8 vals + idx)
_, b = bits(0.25, 'sparse_allgather')
w_bits = 2 * (8 * 32.0 + 32.0)            # nb=8 -> kb=2 (one shard)
v_bits = 2 * (2 * (8 * 32.0 + 32.0))      # 2 shards x (nb=8 -> kb=2)
assert b == 2 * (w_bits + v_bits), (b, 2 * (w_bits + v_bits))
print('OK')
""", devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_checkpoint_resume_reproduces_trajectory():
    """training/checkpoints.py round-trip: save -> restore -> resume
    equals the uninterrupted run, including the variant-bearing state
    (gradient variant's eval-reuse cache; engine-level h_ij)."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.models import Model, get_smoke_config
from repro.core.sharded import ShardedDashaConfig
from repro.training.checkpoints import (latest_step, restore_checkpoint,
                                        save_checkpoint)
from repro.training.trainer import Trainer, TrainerConfig
from repro.training.optim import adamw_server
from repro.data.sharding import place_batch
import tempfile

mesh = make_mesh((4, 2), ('data', 'model'))
cfg = get_smoke_config('granite-3-2b').with_overrides(vocab_size=64)
model = Model(cfg)
dcfg = ShardedDashaConfig(gamma=0.0, a=0.02, b=0.9, p_a=0.5,
                          sampler='independent', compression_ratio=0.1,
                          block_size=64, data_axes=('data',),
                          variant='gradient')
tr = Trainer(model, mesh, TrainerConfig(dasha=dcfg,
                                        server=adamw_server(lr=3e-3,
                                                            warmup=5)))
toks = jnp.tile(jnp.arange(32) % 7, (4, 2, 1)).astype(jnp.int32)
batch = {'tokens': toks}
step = tr.jit_train_step(batch)
ckpt = tempfile.mkdtemp()

with use_mesh(mesh):
    placed = place_batch(batch, mesh, ('data',))
    # uninterrupted 6 steps; snapshot a copy at step 3
    state = tr.init(jax.random.key(0))
    for i in range(6):
        if i == 3:
            save_checkpoint(ckpt, state, step=3)
        state, m = step(state, placed, jax.random.key(i))
    # restore at 3 and resume 3 more with the same keys
    assert latest_step(ckpt) == 3
    like = tr.init(jax.random.key(0))
    resumed = restore_checkpoint(ckpt, like)
    for i in range(3, 6):
        resumed, m2 = step(resumed, placed, jax.random.key(i))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
    assert float(m.loss) == float(m2.loss)
print('OK')
""")
    assert "OK" in out


@pytest.mark.slow
def test_trainer_page_and_gradient_cache():
    """Trainer satellites: (1) the page variant's two-batch-shape step
    runs and logs wire metrics; (2) the gradient variant's eval-reuse
    cache leaves the trajectory unchanged vs recomputing the old-point
    gradients."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.models import Model, get_smoke_config
from repro.core.sharded import ShardedDashaConfig
from repro.training.trainer import Trainer, TrainerConfig
from repro.training.optim import adamw_server
from repro.data.sharding import place_batch

mesh = make_mesh((4, 2), ('data', 'model'))
cfg = get_smoke_config('granite-3-2b').with_overrides(vocab_size=64)
model = Model(cfg)
toks = jnp.tile(jnp.arange(32) % 7, (4, 2, 1)).astype(jnp.int32)
batch = {'tokens': toks}

def run(variant, steps, cache=None):
    dcfg = ShardedDashaConfig(gamma=0.0, a=0.02, b=0.9, p_a=0.5,
                              sampler='independent',
                              compression_ratio=0.1, block_size=64,
                              data_axes=('data',), variant=variant,
                              p_page=0.5)
    tr = Trainer(model, mesh, TrainerConfig(
        dasha=dcfg, server=adamw_server(lr=3e-3, warmup=5),
        cache_old_grads=cache))
    state = tr.init(jax.random.key(0))
    step = tr.jit_train_step(batch)
    mets = []
    with use_mesh(mesh):
        placed = place_batch(batch, mesh, ('data',))
        for i in range(steps):
            state, m = step(state, placed, jax.random.key(i))
            mets.append((float(m.loss), float(m.grad_norm),
                         float(m.bits_sent), float(m.participants)))
    return mets, state

mets, _ = run('page', 8)
assert all(np.isfinite(v) for row in mets for v in row)
# bits surfaced and proportional to the realized participant count
assert any(row[2] > 0 for row in mets)
per_node = {row[2] / row[3] for row in mets if row[3] > 0}
assert len(per_node) == 1, per_node
print('page ok', mets[-1])

m_cache, st_c = run('gradient', 8, cache=True)
m_fresh, st_f = run('gradient', 8, cache=False)
for a, b in zip(m_cache, m_fresh):
    np.testing.assert_allclose(a, b, rtol=1e-6)
for a, b in zip(jax.tree.leaves(st_c.params), jax.tree.leaves(st_f.params)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
print('cache ok')
print('OK')
""")
    assert "OK" in out


@pytest.mark.slow
def test_trainer_finite_mvr_component_trackers():
    """finite_mvr satellite: the trainer threads (n, B, *param)
    per-example gradients + component_idx through the engine's h_ij
    trackers.  Parity anchor: with B = m (all components every round,
    zero-init trackers) the Alg. 4 update reduces EXACTLY to the Alg. 2
    gradient rule — mean_j h_ij ≡ h_i by induction — so the finite_mvr
    trainer must reproduce the gradient-variant trajectory; B < m must
    run, stay finite, and account bits."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.models import Model, get_smoke_config
from repro.core.sharded import ShardedDashaConfig
from repro.training.trainer import Trainer, TrainerConfig
from repro.training.optim import adamw_server
from repro.data.sharding import place_batch

mesh = make_mesh((4, 2), ('data', 'model'))
cfg = get_smoke_config('granite-3-2b').with_overrides(vocab_size=64)
model = Model(cfg)
toks = jnp.tile(jnp.arange(32) % 7, (4, 2, 1)).astype(jnp.int32)
batch = {'tokens': toks}

def run(variant, steps, **tkw):
    dcfg = ShardedDashaConfig(gamma=0.0, a=0.02, b=0.9, p_a=0.5,
                              sampler='independent', compression_ratio=0.1,
                              block_size=64, data_axes=('data',),
                              variant=variant)
    tr = Trainer(model, mesh, TrainerConfig(
        dasha=dcfg, server=adamw_server(lr=3e-3, warmup=5), **tkw))
    state = tr.init(jax.random.key(0))
    step = tr.jit_train_step(batch)
    mets = []
    with use_mesh(mesh):
        placed = place_batch(batch, mesh, ('data',))
        for i in range(steps):
            state, m = step(state, placed, jax.random.key(i))
            mets.append((float(m.loss), float(m.grad_norm),
                         float(m.bits_sent), float(m.participants)))
    return mets, state

m_fin, st_f = run('finite_mvr', 6, num_components=2, component_batch=2)
m_grad, st_g = run('gradient', 6)
for a, b in zip(m_fin, m_grad):
    np.testing.assert_allclose(a, b, rtol=1e-4)
for a, b in zip(jax.tree.leaves(st_f.params), jax.tree.leaves(st_g.params)):
    # per-example vs full-batch vjp sum order, amplified through adamw:
    # loose-ish atol, still trajectory-tight
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-3, atol=2e-4)
assert st_f.dasha.h_ij is not None
print('B=m parity ok', m_fin[-1])

m1, _ = run('finite_mvr', 6, num_components=2, component_batch=1)
assert all(np.isfinite(v) for row in m1 for v in row)
per_node = {row[2] / row[3] for row in m1 if row[3] > 0}
assert len(per_node) == 1, per_node
print('B<m ok', m1[-1])
print('OK')
""")
    assert "OK" in out


@pytest.mark.slow
def test_wire_formats_parity_and_bits():
    """TopK / RandomDithering wire formats in the sharded sparse wire
    (satellite): with matched keys they reproduce the reference DashaPP
    run with the corresponding reference compressor, jnp and pallas,
    and bits_sent follows the per-format accounting."""
    out = run_sub("""
import math
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, use_mesh
from repro.core import RandomDithering, SNice, TopK, variants
from repro.core.dasha_pp import DashaPP, DashaPPConfig
from repro.core.sharded import ShardedDasha, ShardedDashaConfig
from repro.core.problems import (LogisticSigmoidProblem,
                                 make_synthetic_classification,
                                 sample_batch_indices)

n, m, d, B, T = 4, 6, 32, 2, 8
feats, y = make_synthetic_classification(jax.random.key(0), n_nodes=n,
                                         m_per_node=m, d=d)
prob = LogisticSigmoidProblem(feats, y)
mesh = make_mesh((4,), ('data',))
RUN = jax.random.key(42)
x0 = jnp.zeros(d)
samp = SNice(n=n, s=2)
gamma, a, b, ratio = 0.05, 0.1, 0.3, 0.25

def ref_run(compressor):
    alg = DashaPP(prob, compressor, samp,
                  DashaPPConfig('mvr', gamma=gamma, a=a, b=b,
                                batch_size=B))
    st = alg.init(jax.random.key(0), x0)
    step = jax.jit(alg.step)
    for t in range(T):
        st, _ = step(jax.random.fold_in(RUN, t), st)
    return st

def sharded_run(wire, pallas):
    cfg = ShardedDashaConfig(gamma=gamma, a=a, b=b, p_a=0.5,
                             sampler='s_nice', compression_ratio=ratio,
                             block_size=8, aggregation='sparse_allgather',
                             data_axes=('data',), variant='mvr',
                             wire_format=wire, use_pallas=pallas)
    eng = ShardedDasha(mesh, {'w': P()}, cfg)
    @jax.jit
    def round_fn(x, st, key):
        xn = eng.server_step(x, st)
        _, k_oracle, _ = variants.round_keys(key, st.step)
        idx = sample_batch_indices(k_oracle, n, m, B, replace=True)
        gn = {'w': prob.batch_grad(xn['w'], idx)}
        go = {'w': prob.batch_grad(x['w'], idx)}
        st2, met = eng.node_update(gn, go, st, key)
        return xn, st2, met
    with use_mesh(mesh):
        st = eng.init({'w': prob.grad(x0)})
        x = {'w': x0}
        for t in range(T):
            x, st, met = round_fn(x, st, RUN)
    return x['w'], st, met, eng

for wire, comp in [('topk', TopK(k=max(1, math.ceil(ratio * d)))),
                   ('dithering', RandomDithering(s=4))]:
    st_ref = ref_run(comp)
    for pallas in (False, True):
        x_sh, st_sh, met, eng = sharded_run(wire, pallas)
        for name, a_, b_ in [('x', st_ref.x, x_sh),
                             ('g', st_ref.g, st_sh.g['w']),
                             ('g_i', st_ref.g_i, st_sh.g_i['w'])]:
            np.testing.assert_allclose(
                np.asarray(a_), np.asarray(b_), rtol=1e-4, atol=1e-5,
                err_msg=f'{wire}/pallas={pallas}/{name}')
        per_node = eng.uplink_bits_per_round(d) / eng.cfg.p_a
        assert float(met.bits_sent) == float(met.participants) * per_node
        print('wire ok', wire, pallas)
print('OK')
""", devices=4)
    assert "OK" in out


@pytest.mark.slow
def test_full_trainer_loss_decreases_on_learnable_data():
    """End-to-end Trainer on a tiny LM whose data is learnable (constant
    token pattern) — loss must drop."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.models import Model, get_smoke_config
from repro.core.sharded import ShardedDashaConfig
from repro.training.trainer import Trainer, TrainerConfig
from repro.training.optim import adamw_server
from repro.data.sharding import place_batch

mesh = make_mesh((4, 2), ('data', 'model'))
cfg = get_smoke_config('granite-3-2b').with_overrides(vocab_size=64)
model = Model(cfg)
dcfg = ShardedDashaConfig(gamma=0.0, a=0.02, b=0.9, p_a=0.5,
                          sampler='independent', compression_ratio=0.1,
                          block_size=64, data_axes=('data',))
tr = Trainer(model, mesh, TrainerConfig(dasha=dcfg,
                                        server=adamw_server(lr=3e-3, warmup=5)))
state = tr.init(jax.random.key(0))
toks = jnp.tile(jnp.arange(32) % 7, (4, 2, 1)).astype(jnp.int32)
batch = {'tokens': toks}
step = tr.jit_train_step(batch)
losses = []
with use_mesh(mesh):
    placed = place_batch(batch, mesh, ('data',))
    for i in range(60):
        state, m = step(state, placed, jax.random.key(i))
        losses.append(float(m.loss))
print('first', losses[0], 'last', losses[-1])
assert losses[-1] < 0.6 * losses[0], (losses[0], losses[-1])
print('OK')
""")
    assert "OK" in out
