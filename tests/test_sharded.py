"""SPMD runtime tests on a small host-device mesh.

These need >1 CPU device, so they run in a SUBPROCESS that sets
XLA_FLAGS before importing jax (the main pytest process keeps the
default 1-device view, as required for the smoke tests)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import make_mesh, use_mesh
from repro.core.sharded import (ShardedDasha, ShardedDashaConfig,
                                per_node_value_and_grads)
mesh = make_mesh((4, 2), ('data', 'model'))
def loss_fn(params, batch):
    x, y = batch
    return jnp.mean((x @ params['w'] - y) ** 2)
D = 64
params = {'w': jax.random.normal(jax.random.key(0), (D, 8)) * 0.1}
specs = {'w': P(None, 'model')}
xb = jax.random.normal(jax.random.key(1), (4, 32, D))
yb = xb @ jax.random.normal(jax.random.key(2), (D, 8))
def fit(cfg, steps=250):
    eng = ShardedDasha(mesh, specs, cfg)
    with use_mesh(mesh):
        p = {'w': jax.device_put(params['w'], NamedSharding(mesh, P(None, 'model')))}
        @jax.jit
        def step(params_, state, key):
            pn = eng.server_step(params_, state)
            _, gn = per_node_value_and_grads(loss_fn, pn, (xb, yb))
            _, go = per_node_value_and_grads(loss_fn, params_, (xb, yb))
            return pn, eng.node_update(gn, go, state, key)
        _, g0 = per_node_value_and_grads(loss_fn, p, (xb, yb))
        st = eng.init(g0)
        for i in range(steps):
            p, st = step(p, st, jax.random.key(i))
        l = loss_fn(p, (xb, yb))
    return float(l), jax.device_get(st.g['w'])
"""


@pytest.mark.slow
def test_sharded_dasha_converges_and_modes_agree():
    out = run_sub(COMMON + """
base = dict(gamma=0.02, a=0.5/7, b=1/3, p_a=0.5, sampler='independent',
            block_size=8, data_axes=('data',))
l_sparse, g_sparse = fit(ShardedDashaConfig(compression_ratio=0.25,
                                            aggregation='sparse_allgather', **base))
l_dense, g_dense = fit(ShardedDashaConfig(compression_ratio=0.25,
                                          aggregation='dense_psum', **base))
l_id, _ = fit(ShardedDashaConfig(compression_ratio=None, **base))
assert l_sparse < 8.0, l_sparse        # converging (start ~58, 10x drop)
np.testing.assert_allclose(g_sparse, g_dense, rtol=1e-5, atol=1e-6)
assert l_id < 8.0
print('OK', l_sparse, l_dense, l_id)
""")
    assert "OK" in out


@pytest.mark.slow
def test_sharded_pallas_path_matches_jnp():
    """The fused kernel path must reproduce the jnp trajectory in every
    aggregation mode (sparse wire, dense psum, uncompressed)."""
    out = run_sub(COMMON + """
base = dict(gamma=0.02, a=0.5/7, b=1/3, p_a=0.5, sampler='independent',
            block_size=8, data_axes=('data',))
for extra in (dict(compression_ratio=0.25, aggregation='sparse_allgather'),
              dict(compression_ratio=0.25, aggregation='dense_psum'),
              dict(compression_ratio=None)):
    _, g_jnp = fit(ShardedDashaConfig(use_pallas=False, **base, **extra),
                   steps=40)
    _, g_pal = fit(ShardedDashaConfig(use_pallas=True, **base, **extra),
                   steps=40)
    np.testing.assert_allclose(g_jnp, g_pal, rtol=1e-5, atol=1e-6)
    print('mode ok', extra)
print('OK')
""")
    assert "OK" in out


@pytest.mark.slow
def test_full_trainer_loss_decreases_on_learnable_data():
    """End-to-end Trainer on a tiny LM whose data is learnable (constant
    token pattern) — loss must drop."""
    out = run_sub("""
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.models import Model, get_smoke_config
from repro.core.sharded import ShardedDashaConfig
from repro.training.trainer import Trainer, TrainerConfig
from repro.training.optim import adamw_server
from repro.data.sharding import place_batch

mesh = make_mesh((4, 2), ('data', 'model'))
cfg = get_smoke_config('granite-3-2b').with_overrides(vocab_size=64)
model = Model(cfg)
dcfg = ShardedDashaConfig(gamma=0.0, a=0.02, b=0.9, p_a=0.5,
                          sampler='independent', compression_ratio=0.1,
                          block_size=64, data_axes=('data',))
tr = Trainer(model, mesh, TrainerConfig(dasha=dcfg,
                                        server=adamw_server(lr=3e-3, warmup=5)))
state = tr.init(jax.random.key(0))
toks = jnp.tile(jnp.arange(32) % 7, (4, 2, 1)).astype(jnp.int32)
batch = {'tokens': toks}
step = tr.jit_train_step(batch)
losses = []
with use_mesh(mesh):
    placed = place_batch(batch, mesh, ('data',))
    for i in range(60):
        state, m = step(state, placed, jax.random.key(i))
        losses.append(float(m.loss))
print('first', losses[0], 'last', losses[-1])
assert losses[-1] < 0.6 * losses[0], (losses[0], losses[-1])
print('OK')
""")
    assert "OK" in out
