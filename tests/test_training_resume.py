"""train() resume correctness (training/loop.py): round keys and
checkpoint numbering derive from the GLOBAL step in state.step, so a
resumed run continues the randomness stream instead of replaying round
0's and never clobbers the earlier run's checkpoint files.  Subprocess
+ host mesh, same pattern as tests/test_sharded.py."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, devices: int = 8, timeout: int = 520) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=REPO)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


@pytest.mark.slow
def test_train_loop_resume_trajectory_parity():
    """save -> restore -> resume THROUGH train() equals the
    uninterrupted train() run (gradient variant: the eval-reuse cache
    leaves round-trip through restore_checkpoint), and the resumed
    run's checkpoints extend the numbering instead of overwriting the
    earlier files."""
    out = run_sub("""
import glob, os, tempfile
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, use_mesh
from repro.models import Model, get_smoke_config
from repro.core.sharded import ShardedDashaConfig
from repro.training.checkpoints import latest_step, restore_checkpoint
from repro.training.loop import train
from repro.training.trainer import Trainer, TrainerConfig
from repro.training.optim import adamw_server
from repro.training.metrics import MetricsLogger

mesh = make_mesh((4, 2), ('data', 'model'))
cfg = get_smoke_config('granite-3-2b').with_overrides(vocab_size=64)
model = Model(cfg)
dcfg = ShardedDashaConfig(gamma=0.0, a=0.02, b=0.9, p_a=0.5,
                          sampler='independent', compression_ratio=0.1,
                          block_size=64, data_axes=('data',),
                          variant='gradient')

def make_trainer():
    return Trainer(model, mesh, TrainerConfig(
        dasha=dcfg, server=adamw_server(lr=3e-3, warmup=5)))

toks = jnp.tile(jnp.arange(32) % 7, (4, 2, 1)).astype(jnp.int32)
batch = {'tokens': toks}

def fixed():
    while True:
        yield batch

quiet = lambda: MetricsLogger(print_every=1000)
ckpt = tempfile.mkdtemp()
with use_mesh(mesh):
    # uninterrupted 6 steps, checkpoints at global steps 3 and 6
    tr = make_trainer()
    full = train(tr, tr.init(jax.random.key(0)), fixed(), num_steps=6,
                 checkpoint_dir=ckpt, checkpoint_every=3, seed=11,
                 logger=quiet())
    files_a = sorted(glob.glob(os.path.join(ckpt, 'ckpt_*.npz')))
    assert [os.path.basename(f) for f in files_a] == [
        'ckpt_00000003.npz', 'ckpt_00000006.npz'], files_a

    # restore at 3 and resume 3 more steps THROUGH train()
    tr2 = make_trainer()
    like = tr2.init(jax.random.key(0))
    restored = restore_checkpoint(ckpt, like, step=3)
    assert int(jax.device_get(restored.step)) == 3
    # the gradient-variant cache leaves round-tripped
    assert len(jax.tree.leaves(restored.cache)) == \
        len(jax.tree.leaves(like.cache)) > 0
    resumed = train(tr2, restored, fixed(), num_steps=3,
                    checkpoint_dir=ckpt, checkpoint_every=3, seed=11,
                    logger=quiet())

    # trajectory parity with the uninterrupted run (pre-fix, the resume
    # replayed round 0-2 keys and diverged)
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(resumed)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)

    # the resumed run saved at global step 6 — it did NOT overwrite the
    # step-3 file (pre-fix it saved at local i+1 = 3)
    files_b = sorted(glob.glob(os.path.join(ckpt, 'ckpt_*.npz')))
    assert files_b == files_a
    assert latest_step(ckpt) == 6
    re3 = restore_checkpoint(ckpt, like, step=3)
    assert int(jax.device_get(re3.step)) == 3
print('OK')
""")
    assert "OK" in out
