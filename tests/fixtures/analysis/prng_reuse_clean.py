"""prng-reuse near-misses: the derivation idioms the repo uses."""
import jax


def split_between_uses(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (4,))
    b = jax.random.uniform(k2, (4,))
    return a + b


def fold_per_round(run_key, n):
    total = 0.0
    for t in range(n):
        key_t = jax.random.fold_in(run_key, t)   # fresh every iteration
        total += jax.random.normal(key_t, ())
    return total


def branch_arms_are_exclusive(key, flag):
    if flag:
        return jax.random.normal(key, ())
    return jax.random.uniform(key, ())           # other arm: one use


def early_return(key, replace):
    keys = jax.random.split(key, 4)
    if replace:
        return jax.vmap(lambda k: jax.random.normal(k, ()))(keys)
    return jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)


def deriver_helpers(key_t):
    # *_keys-named helpers are derivation boundaries, then one use
    k_part, k_comp = round_keys(key_t)
    return jax.random.bernoulli(k_part), jax.random.normal(k_comp, ())


def round_keys(key):
    keys = jax.random.split(key, 2)
    return keys[0], keys[1]


def host_introspection(cfg):
    keys = cfg.keys()                            # dict keys, not PRNG
    return sorted(keys), list(keys)
