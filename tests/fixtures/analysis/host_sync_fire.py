"""host-sync positive: device syncs inside per-step and driver loops."""
import jax
import jax.numpy as jnp
import numpy as np


class Loop:
    def step(self, state):
        logits = jax.nn.softmax(state)
        tok = np.asarray(jnp.argmax(logits))        # FIRE: np.asarray(device)
        loss = float(jnp.mean(logits))              # FIRE: float(device)
        return tok, loss

    def helper(self, x):
        # transitively hot: called from step-family methods elsewhere
        return x

    def commit(self, contribs):
        total = jnp.sum(jnp.stack(contribs))
        return total.item()                         # FIRE: .item()


def train(n):
    metrics = []
    for t in range(n):
        val = jax.random.uniform(jax.random.PRNGKey(t))
        val.block_until_ready()                     # FIRE: driver-loop block
        out = jax.device_get(val)                   # FIRE: driver-loop get
        metrics.append(out)
    return metrics
