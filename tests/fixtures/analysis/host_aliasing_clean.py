"""host-aliasing near-misses: the synchronous-copy idiom and fresh
per-iteration buffers."""
import jax.numpy as jnp
import numpy as np


def copied_before_handoff(n):
    buf = np.zeros(n)
    dev = jnp.asarray(buf.copy())           # snapshot: owned buffer
    arr = jnp.asarray(np.array(buf))        # np.array also copies
    buf[0] = 1.0
    return dev, arr


def fresh_each_iteration(n, rounds):
    out = []
    for _ in range(rounds):
        keep = np.zeros(n, np.float32)      # rebound every iteration:
        keep[:2] = 1.0                      # no cross-iteration race
        out.append(jnp.asarray(keep))
    return out


class Engine:
    def __init__(self, n):
        self._table = np.zeros((n, 4), np.int32)
        self._lens = np.zeros(n, np.int32)

    def snapshot(self):
        # the discipline the checker wants: copy at the conversion
        return (jnp.asarray(self._table.copy()),
                jnp.asarray(self._lens.copy()))

    def bump(self, i):
        self._lens[i] += 1
        self._table[i, 0] = 7


def call_results_are_fresh(store, idx):
    # conversions of call results never fire (owned by construction)
    return jnp.asarray(store.gather("h", idx))
