"""host-sync near-misses: host-only casts, post-loop reads, admission
work outside driver loop bodies."""
import jax
import jax.numpy as jnp
import numpy as np


class Loop:
    def step(self, state):
        # casts over host values: numpy results never taint
        q_lens = np.asarray([1, 2, 3])
        n = int(q_lens.sum())
        frac = float(np.mean(q_lens))
        fresh = np.asarray([n, n])          # asarray over a host list
        return state, frac, fresh

    def admit(self, req):
        # not a hot name: per-request work may sync freely
        return float(jnp.mean(req))


def train(n):
    total = jnp.zeros(())
    for t in range(n):
        total = total + jax.random.uniform(jax.random.PRNGKey(t))
    return float(jax.device_get(total))     # after the loop: one sync
