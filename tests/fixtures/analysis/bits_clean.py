"""bit-accounting near-misses: core-sourced widths, non-bits math."""
from repro.core import wire


def group_cost(nnz, d):
    bits = wire.GROUP_HEADER_BITS + wire.payload_bits(nnz, d)
    return bits


def payload_bits(nnz, d, value_bits=wire.FLOAT_BITS):
    return nnz * (value_bits + wire.index_bits(d))


def shifted_index(x):
    page = x << 5           # shift amount, not bit accounting
    return page


def unrelated_math(n):
    total = n * 32          # width-looking literal, no bits context
    return total
