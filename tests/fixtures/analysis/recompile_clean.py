"""recompile-hazard near-misses: factories, module-scope jits,
loop-invariant statics."""
from functools import partial

import jax


@partial(jax.jit, static_argnames=("width",))
def stepper(x, width=4):
    return x * width


DOUBLE = jax.jit(lambda v: v * 2)       # module scope: compiled once


def jit_train_step(model):
    """Factory (trainer idiom): the jit IS the product."""
    return jax.jit(model.apply, donate_argnums=(0,))


def sweep(xs, width):
    outs = []
    for x in xs:
        outs.append(stepper(x, width=width))    # loop-invariant static
    return outs


def main():
    f = jax.jit(lambda v: v + 1)    # one-shot CLI jit: not a hot path
    return f(1.0)
