"""Suppression-machinery fixture: one justified, one reason-less, one
unknown id, one multi-line-statement standalone."""
import jax


def justified(key):
    a = jax.random.normal(key, ())
    # repro: ignore[prng-reuse] -- fixture: deliberate reuse, the
    # callee derives domain-separated streams internally
    b = jax.random.uniform(key, ())
    return a + b


def missing_reason(key):
    a = jax.random.normal(key, ())
    b = jax.random.uniform(key, ())  # repro: ignore[prng-reuse]
    return a + b


def unknown_id(key):
    a = jax.random.normal(key, ())
    # repro: ignore[no-such-checker] -- typo'd checker id
    b = jax.random.uniform(key, ())
    return a + b


def multiline_statement(key, model):
    mask = jax.random.bernoulli(key, 0.5, (8,))
    # repro: ignore[prng-reuse] -- covers the whole call even though
    # the key sits on the second physical line
    out = model.apply(mask,
                      key)
    return out
