"""host-aliasing positive: live numpy buffers handed to jnp.asarray."""
import jax.numpy as jnp
import numpy as np


def later_mutation(n):
    buf = np.zeros(n)
    dev = jnp.asarray(buf)                  # FIRE: buf mutated below
    buf[0] = 1.0
    return dev


class Engine:
    def __init__(self, n):
        self._table = np.zeros((n, 4), np.int32)
        self._lens = np.zeros(n, np.int32)

    def snapshot(self):
        # FIRE x2: this class mutates both buffers in place
        return (jnp.asarray(self._table[:, :2]),
                jnp.asarray(self._lens))

    def bump(self, i):
        self._lens[i] += 1
        self._table[i, 0] = 7
