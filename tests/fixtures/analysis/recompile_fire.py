"""recompile-hazard positives: per-iteration jits, loop-varying
statics, unhashable static defaults."""
from functools import partial

import jax


@partial(jax.jit, static_argnames=("width",))
def stepper(x, width=4):
    return x * width


@partial(jax.jit, static_argnames=("shape",))
def alloc(x, shape=[4, 4]):     # FIRE: unhashable static default
    return x.reshape(shape)


def jit_in_loop(xs):
    outs = []
    for x in xs:
        f = jax.jit(lambda v: v * 2)    # FIRE: fresh cache per iteration
        outs.append(f(x))
    return outs


class Runner:
    def step(self, x):
        f = jax.jit(lambda v: v + 1)    # FIRE: cache dies with the call
        return f(x)


def sweep(xs):
    outs = []
    for w, x in enumerate(xs):
        # FIRE: loop counter into a static parameter — one executable
        # per distinct value
        outs.append(stepper(x, width=w))
    return outs
