"""bit-accounting positives: local wire models outside core/."""

HEADER_BITS = 32            # FIRE: width literal on a *_BITS name


def payload_bits(nnz, d, value_bits=32.0):   # FIRE: width default
    return nnz * (value_bits + 9.0)


def wire_cost(k, d):
    bits = k * 32 + d       # FIRE: width arithmetic into a bits name
    return bits


def report(log, n):
    log(total_bits=n * 64.0)    # FIRE: width arithmetic into *bits* kwarg


def uplink_bits(k):
    return k * 32 + 16      # FIRE: width arithmetic returned from *bits*
