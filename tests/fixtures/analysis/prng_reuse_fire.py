"""prng-reuse positive: keys consumed twice without re-derivation."""
import jax


def double_consumption(key):
    a = jax.random.normal(key, (4,))
    b = jax.random.uniform(key, (4,))       # FIRE: same key, second draw
    return a + b


def loop_replay(key, n):
    total = 0.0
    for _ in range(n):
        # FIRE on the second symbolic iteration: no fold_in/split
        # between iterations — every round replays round 0
        total += jax.random.normal(key, ())
    return total


def two_consumers(key, model):
    mask = jax.random.bernoulli(key, 0.5, (8,))
    out = model.apply(key, mask)            # FIRE: second consumption
    return out
