"""pallas-contract near-misses: the dasha_update/paged_attention
idioms, dimensionally consistent and comfortably inside ~16 MB VMEM.

Never imported — the linter fixtures are parsed, not executed.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
DEFAULT_BLOCK_ROWS = 512


def _specs(rows, block_rows=DEFAULT_BLOCK_ROWS):
    """Helper the checker's resolver must follow (dasha_update idiom)."""
    grid = (rows // block_rows,)
    tile = (block_rows, LANES)
    return grid, tile


def kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def add(x, y, block_rows=DEFAULT_BLOCK_ROWS):
    grid, tile = _specs(4096, block_rows)
    spec = pl.BlockSpec(tile, lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((4096, LANES), jnp.float32),
    )(x, y)


def gather_kernel(idx_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...]


def page_lookup(i, idx_ref):
    """Named index_map (paged_attention idiom)."""
    return idx_ref[i], 0


def gather_rows(table, idx):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(8,),
        in_specs=[pl.BlockSpec((DEFAULT_BLOCK_ROWS, LANES),
                               page_lookup)],
        out_specs=pl.BlockSpec((DEFAULT_BLOCK_ROWS, LANES),
                               lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((4096, LANES), jnp.float32),
    )(idx, table)
