"""pallas-contract positives: arity mismatches and a VMEM blowout.

Never imported — the linter fixtures are parsed, not executed.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128
PAGE_VMEM_BUDGET = 4 << 20


def kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def bad_index_map_params(x):
    return pl.pallas_call(
        kernel,
        grid=(4, 2),
        # FIRE: 1 lambda parameter for a 2-axis grid
        in_specs=[pl.BlockSpec((256, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((256, LANES), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((1024, 256), jnp.float32),
    )(x)


def bad_return_arity(x):
    return pl.pallas_call(
        kernel,
        grid=(4,),
        # FIRE: 1 coordinate returned for a 2-dim block
        in_specs=[pl.BlockSpec((256, LANES), lambda i: (i,))],
        out_specs=pl.BlockSpec((256, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((1024, LANES), jnp.float32),
    )(x)


def bad_operand_count(x, y):
    return pl.pallas_call(
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((256, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((256, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((1024, LANES), jnp.float32),
    )(x, y)                     # FIRE: 2 operands, 1 in_spec


def bad_out_arity(x):
    return pl.pallas_call(
        kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((256, LANES), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((256, LANES), lambda i: (i, 0))],
        # FIRE: 1 out_spec for 2 results
        out_shape=[jax.ShapeDtypeStruct((1024, LANES), jnp.float32),
                   jax.ShapeDtypeStruct((1024, LANES), jnp.float32)],
    )(x)


def budget_blowout(x):
    tile = (8192, LANES)        # 4 MB per ref at fp32
    # FIRE: 2 tiles + scratch ~ 8.5 MB > PAGE_VMEM_BUDGET (4 MB)
    return pl.pallas_call(
        kernel,
        grid=(2,),
        in_specs=[pl.BlockSpec(tile, lambda i: (i, 0))],
        out_specs=pl.BlockSpec(tile, lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((16384, LANES), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1024, LANES), jnp.float32)],
    )(x)
