"""The PR 9 static-analysis layer (repro/analysis/, DESIGN.md §14):
the stdlib-ast contract linter that turns this repo's past bug classes
into machine-checked invariants.  Covers every checker against its
fire/clean fixture pair, the suppression + baseline escape hatches,
the CLI exit-code contract, the JSON artifact round-trip through
``repro.obs.validate --analysis``, and the self-scan gate — ``src/``
must stay clean modulo the committed baseline."""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import (Baseline, BaselineError, CHECKER_IDS,
                            default_checkers, run)
from repro.analysis.findings import Finding, SuppressionSet
from repro.obs import validate as obs_validate

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures", "analysis")


def scan(*names, select=None, baseline=None):
    """Run the engine over fixture files; returns the RunResult."""
    paths = [os.path.join(FIXTURES, n) for n in names]
    return run(paths, default_checkers(), baseline=baseline,
               select=select)


def lines_of(result, checker):
    return sorted(f.line for f in result.findings
                  if f.checker == checker)


# ---------------------------------------------------------------------------
# per-checker fixture pairs: each positive fires at the expected lines,
# each clean twin stays silent

FIXTURE_EXPECTATIONS = [
    # (checker id, fire fixture, clean fixture, severity, expected lines)
    ("host-sync", "host_sync_fire.py", "host_sync_clean.py",
     "warn", [10, 11, 20, 27, 28]),
    ("host-aliasing", "host_aliasing_fire.py", "host_aliasing_clean.py",
     "error", [8, 20, 21]),
    ("prng-reuse", "prng_reuse_fire.py", "prng_reuse_clean.py",
     "error", [7, 16, 22]),
    ("pallas-contract", "pallas_contract_fire.py",
     "pallas_contract_clean.py", "error", [23, 34, 41, 51, 65]),
    ("recompile-hazard", "recompile_fire.py", "recompile_clean.py",
     None, [14, 21, 28, 37]),
    ("bit-accounting", "bits_fire.py", "bits_clean.py",
     "warn", [3, 6, 11, 16, 20]),
]


@pytest.mark.parametrize(
    "checker,fire,clean,severity,expected",
    FIXTURE_EXPECTATIONS, ids=[e[0] for e in FIXTURE_EXPECTATIONS])
def test_checker_fires_on_positive_fixture(checker, fire, clean,
                                           severity, expected):
    result = scan(fire, select=[checker])
    assert lines_of(result, checker) == expected, \
        [f.render() for f in result.findings]
    if severity is not None:
        assert all(f.severity == severity for f in result.findings)
    assert all(f.checker == checker for f in result.findings)


@pytest.mark.parametrize(
    "checker,fire,clean,severity,expected",
    FIXTURE_EXPECTATIONS, ids=[e[0] for e in FIXTURE_EXPECTATIONS])
def test_checker_silent_on_clean_fixture(checker, fire, clean,
                                         severity, expected):
    result = scan(clean, select=[checker])
    assert result.findings == [], [f.render() for f in result.findings]
    assert result.suppressed == []


def test_recompile_severities():
    """jit-in-loop and mutable static defaults are errors; jit built
    per step (without the factory-return idiom) is a warning."""
    result = scan("recompile_fire.py", select=["recompile-hazard"])
    by_line = {f.line: f.severity for f in result.findings}
    assert by_line == {14: "error", 21: "error",
                       28: "warn", 37: "warn"}


# ---------------------------------------------------------------------------
# suppression machinery

def test_suppressions_fixture():
    """Justified suppressions silence findings (including across a
    multi-line statement); reason-less or unknown-id suppressions are
    themselves findings and silence nothing they shouldn't."""
    result = scan("suppressions.py")
    sup_lines = sorted(f.line for f in result.suppressed)
    # the multiline finding anchors to the physical line holding the
    # reused key (32), inside the span the standalone comment covers
    assert sup_lines == [10, 32]
    open_prng = lines_of(result, "prng-reuse")
    assert open_prng == [16, 23]          # missing_reason / unknown_id
    sup_findings = sorted(f.line for f in result.findings
                          if f.checker == "suppression")
    assert sup_findings == [16, 22]       # malformed + unknown id


def test_suppression_covers_whole_logical_statement():
    src = ("import jax\n"
           "def f(key, model):\n"
           "    a = jax.random.normal(key, ())\n"
           "    # repro: ignore[prng-reuse] -- callee re-derives\n"
           "    out = model.apply(a,\n"
           "                      key)\n"
           "    return out\n")
    sups = SuppressionSet(src)
    assert len(sups.suppressions) == 1
    sup = sups.suppressions[0]
    assert (sup.line, sup.end_line) == (5, 6)
    hit = Finding("prng-reuse", "x.py", 6, 22, "error", "reused")
    miss = Finding("prng-reuse", "x.py", 3, 8, "error", "reused")
    assert sups.matches(hit)
    assert not sups.matches(miss)


def test_inline_suppression_covers_only_its_line():
    src = ("x = 1  # repro: ignore[host-sync] -- known sync point\n"
           "y = 2\n")
    sups = SuppressionSet(src)
    assert len(sups.suppressions) == 1
    assert sups.matches(Finding("host-sync", "x.py", 1, 0, "warn", "m"))
    assert not sups.matches(Finding("host-sync", "x.py", 2, 0,
                                    "warn", "m"))


def test_suppression_without_reason_is_malformed():
    sups = SuppressionSet("x = 1  # repro: ignore[host-sync]\n")
    assert sups.suppressions == []
    assert len(sups.malformed) == 1


def test_suppression_finding_cannot_self_suppress():
    """A suppression-hygiene finding must not be silenced by the very
    comment it complains about."""
    result = scan("suppressions.py", select=["prng-reuse"])
    assert any(f.checker == "suppression" for f in result.findings)


# ---------------------------------------------------------------------------
# baseline

def test_baseline_is_line_agnostic():
    f = Finding("bit-accounting", "src/x.py", 42, 0, "warn",
                "width literal 32 in bits context")
    b = Baseline([{"checker": f.checker, "path": f.path,
                   "message": f.message,
                   "justification": "legacy wire model, tracked"}])
    assert b.contains(f)
    moved = Finding(f.checker, f.path, 7, 0, f.severity, f.message)
    assert b.contains(moved)
    other = Finding(f.checker, f.path, 42, 0, f.severity, "different")
    assert not b.contains(other)


def test_baseline_rejects_empty_justification():
    with pytest.raises(BaselineError, match="justification"):
        Baseline([{"checker": "host-sync", "path": "a.py",
                   "message": "m", "justification": "  "}])


def test_baseline_load_missing_file_is_empty(tmp_path):
    b = Baseline.load(str(tmp_path / "nope.json"))
    assert not b.contains(Finding("host-sync", "a.py", 1, 0,
                                  "warn", "m"))


def test_baseline_moves_findings_out_of_open():
    result = scan("bits_fire.py", select=["bit-accounting"])
    assert result.findings
    entries = [{"checker": f.checker, "path": f.path,
                "message": f.message,
                "justification": "fixture debt for the test"}
               for f in result.findings]
    again = scan("bits_fire.py", select=["bit-accounting"],
                 baseline=Baseline(entries))
    assert again.findings == []
    assert len(again.baselined) == len(result.findings)


# ---------------------------------------------------------------------------
# CLI contract

def run_cli(*args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True, text=True, cwd=cwd or REPO, env=env)


def test_cli_exit_codes(tmp_path):
    clean = run_cli(os.path.join(FIXTURES, "host_sync_clean.py"),
                    "--baseline", str(tmp_path / "none.json"))
    assert clean.returncode == 0, clean.stderr
    dirty = run_cli(os.path.join(FIXTURES, "prng_reuse_fire.py"),
                    "--baseline", str(tmp_path / "none.json"))
    assert dirty.returncode == 1
    assert "prng-reuse" in dirty.stdout
    missing = run_cli(str(tmp_path / "no_such_dir"))
    assert missing.returncode == 2


def test_cli_list_names_every_checker():
    proc = run_cli("--list")
    assert proc.returncode == 0
    for cid in CHECKER_IDS:
        assert cid in proc.stdout


def test_cli_rejects_unknown_select():
    proc = run_cli("--select", "no-such-checker", FIXTURES)
    assert proc.returncode == 2


def test_cli_update_baseline_skeleton_needs_justifications(tmp_path):
    base = str(tmp_path / "base.json")
    proc = run_cli(os.path.join(FIXTURES, "bits_fire.py"),
                   "--baseline", base, "--update-baseline")
    assert proc.returncode == 0, proc.stderr
    with open(base) as f:
        entries = json.load(f)
    assert entries and all(e["justification"] == "" for e in entries)
    # the skeleton is deliberately unusable until reasons are written
    rerun = run_cli(os.path.join(FIXTURES, "bits_fire.py"),
                    "--baseline", base)
    assert rerun.returncode == 2
    for e in entries:
        e["justification"] = "accepted fixture debt"
    with open(base, "w") as f:
        json.dump(entries, f)
    final = run_cli(os.path.join(FIXTURES, "bits_fire.py"),
                    "--baseline", base)
    assert final.returncode == 0, final.stdout + final.stderr


# ---------------------------------------------------------------------------
# JSON artifact + obs.validate round-trip

def test_artifact_validates_and_counts_statuses(tmp_path):
    out = str(tmp_path / "findings.json")
    proc = run_cli(os.path.join(FIXTURES, "suppressions.py"),
                   "--baseline", str(tmp_path / "none.json"),
                   "--json", out)
    assert proc.returncode == 1
    with open(out) as f:
        doc = json.load(f)
    assert obs_validate.validate_analysis(doc) == []
    kind, errors = obs_validate.validate_file(out)       # auto-detect
    assert (kind, errors) == ("analysis", [])
    assert obs_validate.main(["--analysis", out]) == 0
    statuses = {f["status"] for f in doc["findings"]}
    assert statuses == {"open", "suppressed"}
    assert doc["summary"]["open"] == 4
    assert doc["summary"]["suppressed"] == 2


def test_validate_analysis_rejects_bad_docs():
    assert obs_validate.validate_analysis([]) != []
    base = {"ts": 1.0, "tool": "repro.analysis", "version": 1,
            "paths": ["src"], "findings": [], "summary": {
                "files": 0, "open": 0, "errors": 0, "warnings": 0,
                "suppressed": 0, "baselined": 0}}
    assert obs_validate.validate_analysis(base) == []
    bad_tool = dict(base, tool="other")
    assert any("tool" in e for e in
               obs_validate.validate_analysis(bad_tool))
    bad_finding = dict(base, findings=[{
        "checker": "host-sync", "path": "a.py", "line": 0, "col": 0,
        "severity": "fatal", "message": "m", "status": "open"}])
    errs = obs_validate.validate_analysis(bad_finding)
    assert any("line" in e for e in errs)
    assert any("severity" in e for e in errs)
    drift = dict(base, findings=[{
        "checker": "host-sync", "path": "a.py", "line": 3, "col": 0,
        "severity": "warn", "message": "m", "status": "open"}])
    assert any("summary.open" in e for e in
               obs_validate.validate_analysis(drift))


# ---------------------------------------------------------------------------
# self-scan: the gate CI enforces

def test_self_scan_src_is_clean_modulo_baseline():
    """``python -m repro.analysis src/`` must exit 0 — every remaining
    finding in the repo's own source is either fixed, inline-justified,
    or carries a written justification in the committed baseline."""
    proc = run_cli("src")
    assert proc.returncode == 0, (
        "open findings in src/ — fix them or justify them:\n"
        + proc.stdout + proc.stderr)


def test_registry_ids_are_unique_and_sorted():
    ids = [c.id for c in default_checkers()]
    assert ids == sorted(ids)
    assert len(ids) == len(set(ids))
    assert set(ids) == set(CHECKER_IDS)
