"""Property tests for the unbiased compressors (paper Definition 1):
E[C(x)] = x and E||C(x)-x||^2 <= omega ||x||^2, plus wire formats."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st   # hypothesis or deterministic fallback

from repro.core.compressors import (Composed, Identity, NaturalCompression,
                                    RandK, RandomDithering, TopK)

TRIALS = 512


def _mc_check_unbiased(comp, x, trials=TRIALS, tol=None):
    keys = jax.random.split(jax.random.key(42), trials)
    outs = jax.vmap(lambda k: comp.compress(k, x))(keys)
    mean = jnp.mean(outs, axis=0)
    err = float(jnp.linalg.norm(mean - x) / (jnp.linalg.norm(x) + 1e-12))
    if tol is None:
        # MC std of the mean is ~sqrt(omega/trials)*||x||; allow 3 sigma
        tol = 3.0 * (comp.omega(x.shape[-1]) / trials) ** 0.5 + 0.02
    assert err < tol, f"unbiasedness violated: rel err {err} (tol {tol})"
    # omega bound (Definition 1), with Monte-Carlo slack
    sq = jnp.mean(jnp.sum((outs - x) ** 2, axis=-1))
    bound = comp.omega(x.shape[-1]) * float(jnp.sum(x ** 2))
    assert float(sq) <= bound * 1.3 + 1e-9, (float(sq), bound)


@pytest.mark.parametrize("comp", [
    Identity(),
    RandK(k=3),
    RandK(k=10),
    NaturalCompression(),
    RandomDithering(s=4),
    Composed(inner=RandK(k=8), outer=NaturalCompression()),
])
def test_unbiased_and_omega(comp):
    x = jax.random.normal(jax.random.key(1), (32,))
    _mc_check_unbiased(comp, x)


@settings(max_examples=25, deadline=None)
@given(d=st.integers(4, 64), k=st.integers(1, 64), seed=st.integers(0, 99))
def test_randk_structure(d, k, seed):
    """RandK keeps exactly min(k, d) coords, scaled by d/min(k,d)."""
    comp = RandK(k=k)
    x = jax.random.normal(jax.random.key(seed), (d,)) + 0.1
    out = comp.compress(jax.random.key(seed + 1), x)
    nz = int(jnp.sum(out != 0))
    keff = min(k, d)
    assert nz == keff
    ratio = out[out != 0] / x[out != 0]
    np.testing.assert_allclose(np.asarray(ratio), d / keff, rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(d=st.integers(8, 64), seed=st.integers(0, 99))
def test_natural_within_factor_two(d, seed):
    """Natural compression outputs sign(x) * 2^e with 2^e in [|x|/2, 2|x|]."""
    comp = NaturalCompression()
    x = jax.random.normal(jax.random.key(seed), (d,))
    out = comp.compress(jax.random.key(seed + 1), x)
    nz = x != 0
    r = np.abs(np.asarray(out)[nz] / np.asarray(x)[nz])
    assert np.all(r >= 0.49) and np.all(r <= 2.01)
    assert np.all(np.sign(np.asarray(out)[nz]) == np.sign(np.asarray(x)[nz]))


def test_topk_selects_largest():
    x = jnp.asarray([0.1, -5.0, 0.3, 2.0, -0.2])
    out = TopK(k=2).compress(jax.random.key(0), x)
    np.testing.assert_allclose(np.asarray(out),
                               [0.0, -5.0, 0.0, 2.0, 0.0])


def test_sparse_wire_format_roundtrip():
    comp = RandK(k=4)
    x = jax.random.normal(jax.random.key(3), (16,))
    vals, idx = comp.compress_sparse(jax.random.key(4), x)
    dense = comp.compress(jax.random.key(4), x)
    rebuilt = jnp.zeros_like(x).at[idx].set(vals)
    np.testing.assert_allclose(np.asarray(rebuilt), np.asarray(dense),
                               rtol=1e-6)


def test_wire_bits_ordering():
    d = 1000
    assert RandK(k=10).wire_bits(d) < Identity().wire_bits(d)
    assert (Composed(inner=RandK(k=10), outer=NaturalCompression())
            .wire_bits(d) < RandK(k=10).wire_bits(d))


def test_pp_wrapper_omega():
    """Footnote 3: C^{p_a} in U((w+1)/p_a - 1)."""
    from repro.core.compressors import PartialParticipationCompressor
    inner = RandK(k=8)
    d = 32
    w = inner.omega(d)
    wrapped = PartialParticipationCompressor(inner=inner, p_a=0.25)
    assert np.isclose(wrapped.omega(d), (w + 1) / 0.25 - 1)
    x = jax.random.normal(jax.random.key(5), (d,))
    _mc_check_unbiased(wrapped, x, trials=2048, tol=0.3)
