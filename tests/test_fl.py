"""The async federated runtime (repro/fl, DESIGN.md §9): sync-limit
parity against the reference engine, replay determinism, buffered
first-K vs barrier wall-clock, staleness semantics, dropout/rejoin,
latency-model determinism, and the buffered-commit kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LogisticSigmoidProblem, RandK, RandomDithering,
                        SNice, TopK, make_synthetic_classification)
from repro.core.dasha_pp import DashaPP, DashaPPConfig
from repro.fl import (ARRIVAL, REJOIN, AdaptiveStaleness, AsyncConfig,
                      AsyncDashaServer, ConstantLatency, EventQueue,
                      LognormalLatency, PoissonAvailability,
                      PowerLawStaleness, make_latency, make_staleness)

N, M, D, B = 6, 5, 16, 2


@pytest.fixture(scope="module")
def fl_problem():
    feats, y = make_synthetic_classification(jax.random.key(0),
                                             n_nodes=N, m_per_node=M, d=D)
    return LogisticSigmoidProblem(feats, y)


def _cfg(variant, use_pallas=False):
    return DashaPPConfig(variant, gamma=0.02, a=0.1, b=0.3, p_page=0.4,
                         batch_size=B, use_pallas=use_pallas)


def _run_sync(prob, cfg, rounds=8):
    alg = DashaPP(prob, RandK(k=4), SNice(n=N, s=3), cfg)
    return jax.jit(lambda k: alg.run(k, jnp.zeros(D), rounds))(
        jax.random.key(7))[0]


def _run_async(prob, cfg, acfg, latency, rounds=8, key=7):
    srv = AsyncDashaServer(prob, RandK(k=4), SNice(n=N, s=3), cfg, acfg,
                           latency)
    return srv.run(jax.random.key(key), jnp.zeros(D), rounds)


# ----------------------------------------------------------------------
# Acceptance: sync-limit parity
# ----------------------------------------------------------------------


@pytest.mark.parametrize("variant",
                         ["gradient", "mvr", "page", "finite_mvr"])
def test_sync_limit_parity(fl_problem, variant):
    """Zero latency jitter + buffer = cohort size (and the barrier)
    reproduce the DashaPP trajectory allclose — every variant."""
    st_ref = _run_sync(fl_problem, _cfg(variant))
    for K in (3, None):   # 3 == the s-nice cohort size; None == barrier
        st, res = _run_async(fl_problem, _cfg(variant),
                             AsyncConfig(buffer_size=K),
                             ConstantLatency())
        for name, a, b in [("x", st_ref.x, st.x), ("g", st_ref.g, st.g),
                           ("h_i", st_ref.h_i, st.h_i),
                           ("g_i", st_ref.g_i, st.g_i)]:
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6,
                err_msg=f"{variant}/K={K}/{name}")
        if variant == "finite_mvr":
            np.testing.assert_allclose(np.asarray(st_ref.h_ij),
                                       np.asarray(st.h_ij),
                                       rtol=1e-4, atol=1e-6)
        # every commit is fresh in the sync limit
        assert set(res.staleness_hist) == {0}
        assert res.skipped_busy.sum() == 0


@pytest.mark.parametrize("variant", ["gradient", "page"])
def test_sync_limit_parity_pallas(fl_problem, variant):
    """Fused dispatch + buffered-commit kernel path, same contract."""
    st_ref = _run_sync(fl_problem, _cfg(variant, use_pallas=True))
    st, _ = _run_async(fl_problem, _cfg(variant, use_pallas=True),
                       AsyncConfig(buffer_size=3, use_pallas=True),
                       ConstantLatency())
    np.testing.assert_allclose(np.asarray(st_ref.x), np.asarray(st.x),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_ref.g_i), np.asarray(st.g_i),
                               rtol=1e-4, atol=1e-6)


# ----------------------------------------------------------------------
# Acceptance: replay determinism
# ----------------------------------------------------------------------


def test_replay_determinism(fl_problem):
    """Same seed ⇒ identical event log and bitwise-identical iterate."""
    lat = LognormalLatency(sigma=1.0, client_sigma=1.0, dropout=0.1,
                           bandwidth_bps=1e4, seed=3)
    runs = [_run_async(fl_problem, _cfg("mvr"),
                       AsyncConfig(buffer_size=2), lat, rounds=15,
                       key=5) for _ in range(2)]
    (s1, r1), (s2, r2) = runs
    assert r1.event_log == r2.event_log
    assert len(r1.event_log) > 0
    np.testing.assert_array_equal(np.asarray(s1.x), np.asarray(s2.x))
    np.testing.assert_array_equal(r1.time, r2.time)


def test_different_seed_different_schedule(fl_problem):
    lat = LognormalLatency(sigma=1.0, client_sigma=1.0, seed=3)
    _, r1 = _run_async(fl_problem, _cfg("mvr"),
                       AsyncConfig(buffer_size=2), lat, rounds=10, key=5)
    _, r2 = _run_async(fl_problem, _cfg("mvr"),
                       AsyncConfig(buffer_size=2), lat, rounds=10, key=6)
    assert r1.event_log != r2.event_log


# ----------------------------------------------------------------------
# Acceptance: buffered first-K beats the barrier under heterogeneity
# ----------------------------------------------------------------------


def test_buffered_beats_barrier_wallclock(fl_problem):
    lat = LognormalLatency(sigma=1.0, client_sigma=1.0, seed=3)
    _, res_buf = _run_async(fl_problem, _cfg("mvr"),
                            AsyncConfig(buffer_size=1), lat, rounds=30)
    _, res_bar = _run_async(fl_problem, _cfg("mvr"), AsyncConfig(),
                            lat, rounds=30)
    assert res_buf.total_time < res_bar.total_time
    # the price: stale commits exist (and are logged)
    assert any(s > 0 for s in res_buf.staleness_hist)
    assert all(s == 0 for s in res_bar.staleness_hist)
    # conservation: every dispatched job eventually commits (no drops
    # here), even though the buffered server dispatches fewer jobs —
    # clients rejoin the pool only when their contribution lands
    for res in (res_buf, res_bar):
        assert res.committed.sum() == res.participants.sum()


def test_async_converges_under_heterogeneity(fl_problem):
    lat = LognormalLatency(sigma=0.8, client_sigma=0.8, seed=2)
    _, res = _run_async(fl_problem, _cfg("mvr"),
                        AsyncConfig(buffer_size=2,
                                    staleness_exponent=0.5),
                        lat, rounds=400)
    g = res.grad_norm_sq
    assert np.all(np.isfinite(g))
    # staleness weighting leaves a bias floor, so the bar is looser
    # than the sync engines': a 5x decrease without blowup
    assert np.median(g[-40:]) < 0.2 * g[0], (g[0], np.median(g[-40:]))


# ----------------------------------------------------------------------
# Staleness semantics, dropout/rejoin
# ----------------------------------------------------------------------


def test_max_staleness_discards(fl_problem):
    lat = LognormalLatency(sigma=1.5, client_sigma=1.5, seed=4)
    _, unl = _run_async(fl_problem, _cfg("mvr"),
                        AsyncConfig(buffer_size=1), lat, rounds=40)
    _, cap = _run_async(fl_problem, _cfg("mvr"),
                        AsyncConfig(buffer_size=1, max_staleness=1),
                        lat, rounds=40)
    assert unl.discarded_stale == 0
    assert cap.discarded_stale > 0
    assert max(cap.staleness_hist) <= 1


def test_dropout_and_rejoin(fl_problem):
    lat = LognormalLatency(sigma=0.5, client_sigma=0.5, dropout=0.3,
                           rejoin_s=2.0, bandwidth_bps=1e4, seed=9)
    st, res = _run_async(fl_problem, _cfg("mvr"),
                         AsyncConfig(buffer_size=2), lat, rounds=30)
    assert res.dropped > 0
    kinds = [e[2] for e in res.event_log]
    assert REJOIN in kinds and ARRIVAL in kinds
    # dropped jobs never commit: commits + drops == dispatches
    assert res.committed.sum() + res.dropped == res.participants.sum()
    assert np.all(np.isfinite(res.loss))
    assert np.all(np.isfinite(np.asarray(st.x)))
    # dropped jobs' busy windows are clipped at the final clock
    assert np.all(res.utilization >= 0) and np.all(res.utilization <= 1)


def test_busy_clients_skip_sampling(fl_problem):
    """With a 1-deep buffer and long jobs, sampled-but-busy clients are
    recorded as skipped, and utilization stays in [0, 1]."""
    lat = LognormalLatency(sigma=1.0, client_sigma=1.0, seed=3)
    _, res = _run_async(fl_problem, _cfg("mvr"),
                        AsyncConfig(buffer_size=1), lat, rounds=30)
    assert res.skipped_busy.sum() > 0
    assert np.all(res.utilization >= 0) and np.all(res.utilization <= 1)


def test_bits_on_wire_accounting(fl_problem):
    """Every committed or in-flight-delivered message pays exactly the
    compressor's wire_bits; dropped jobs pay nothing."""
    comp = RandK(k=4)
    lat = LognormalLatency(sigma=0.7, client_sigma=0.7, dropout=0.2,
                           seed=5)
    srv = AsyncDashaServer(fl_problem, comp, SNice(n=N, s=3),
                           _cfg("mvr"), AsyncConfig(buffer_size=2), lat)
    _, res = srv.run(jax.random.key(3), jnp.zeros(D), 25)
    arrivals = sum(1 for e in res.event_log if e[2] == ARRIVAL)
    assert res.bits_cum[-1] == arrivals * comp.wire_bits(D)


@pytest.mark.parametrize("comp", [TopK(k=4), RandomDithering(s=4)])
def test_async_transport_topk_and_dithering(fl_problem, comp):
    """The async client transport runs the TopK / RandomDithering wire
    formats end-to-end with their own bit accounting."""
    srv = AsyncDashaServer(fl_problem, comp, SNice(n=N, s=3),
                           _cfg("mvr"), AsyncConfig(buffer_size=2),
                           LognormalLatency(sigma=0.5, client_sigma=0.5,
                                            bandwidth_bps=1e5, seed=1))
    st, res = srv.run(jax.random.key(2), jnp.zeros(D), 20)
    assert np.all(np.isfinite(res.loss))
    arrivals = sum(1 for e in res.event_log if e[2] == ARRIVAL)
    assert res.bits_cum[-1] == pytest.approx(
        arrivals * comp.wire_bits(D))


# ----------------------------------------------------------------------
# Components: event queue, latency models, buffered-commit kernel
# ----------------------------------------------------------------------


def test_event_queue_deterministic_order():
    q = EventQueue()
    q.push(2.0, ARRIVAL, client=1, round_idx=0)
    q.push(1.0, ARRIVAL, client=2, round_idx=0)
    q.push(1.0, REJOIN, client=3, round_idx=0)   # tie: later seq
    e1, e2 = q.pop(), q.pop()
    # earliest time first; ties break by push order (seq)
    assert (e1.time, e1.client) == (1.0, 2)
    assert (e2.time, e2.client) == (1.0, 3)
    assert q.pop().time == 2.0
    assert len(q) == 0
    assert q.log_tuples()[0] == (1.0, 1, ARRIVAL, 2, 0)


def test_latency_models_deterministic_and_positional():
    lat = LognormalLatency(sigma=0.5, client_sigma=0.5,
                           bandwidth_bps=1e5, bandwidth_sigma=0.3,
                           dropout=0.2, seed=7)
    a = lat.job(3, 11, uplink_bits=1e4)
    b = lat.job(3, 11, uplink_bits=1e4)
    assert a == b                              # keyed by position
    assert a != lat.job(3, 12, uplink_bits=1e4)
    assert a != lat.job(4, 11, uplink_bits=1e4)
    assert a.compute_s > 0 and a.network_s > 0
    const = ConstantLatency(compute_s=2.0)
    t = const.job(0, 0, uplink_bits=1e6)
    assert t.compute_s == 2.0 and t.network_s == 0.0 and not t.dropped
    assert isinstance(make_latency("lognormal", sigma=0.1),
                      LognormalLatency)
    with pytest.raises(ValueError):
        make_latency("bogus")


def test_lognormal_fleet_is_persistently_heterogeneous():
    lat = LognormalLatency(sigma=0.0, client_sigma=1.0, seed=0)
    speeds = [lat.job(i, 0, 0.0).compute_s for i in range(10)]
    assert len(set(np.round(speeds, 9))) > 5     # clients differ
    again = [lat.job(i, 1, 0.0).compute_s for i in range(10)]
    np.testing.assert_allclose(speeds, again)    # but persistently


def test_buffered_commit_kernel_matches_jnp():
    from repro.kernels.ops import buffered_commit_op
    key = jax.random.key(0)
    for kk, d in ((3, 50), (8, 1000), (1, 7)):
        g = jax.random.normal(jax.random.fold_in(key, d), (d,))
        m = jax.random.normal(jax.random.fold_in(key, d + 1), (kk, d))
        w = jax.random.uniform(jax.random.fold_in(key, d + 2), (kk,))
        got = buffered_commit_op(g, m, w, n_nodes=6)
        want = g + (w @ m) / 6.0
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def test_async_config_validation():
    with pytest.raises(ValueError):
        AsyncConfig(buffer_size=0)
    AsyncConfig(buffer_size=None)   # barrier is fine
    with pytest.raises(ValueError):
        AsyncConfig(staleness_policy="bogus")


def test_server_clock_advances_through_fleet_wide_outage(fl_problem):
    """Frozen-clock guard: availability is a function of virtual time,
    so when the whole fleet is idle-but-offline with nothing in flight
    the server must tick the clock forward for the outage windows to
    ever end — pre-fix, `now` froze and the fleet never recovered."""
    av = PoissonAvailability(rate=5.0, off_mean=3.0, seed=7)
    srv = AsyncDashaServer(fl_problem, RandK(k=4), SNice(n=N, s=3),
                           _cfg("mvr"), AsyncConfig(buffer_size=2),
                           ConstantLatency(compute_s=0.5),
                           availability=av)
    _, res = srv.run(jax.random.key(1), jnp.zeros(D), 60)
    assert res.skipped_offline.sum() > 0          # outages really hit
    half = len(res.participants) // 2
    assert res.participants[half:].sum() > 0      # ...and ended
    assert res.committed.sum() > 0
    assert res.total_time > 1.0                   # the clock moved


def test_cohort_scheduler_accepts_dropout_latency():
    """Mid-flight dropout landed (DESIGN.md §12): a dropout-configured
    latency model is accepted by the scheduler — the old hard rejection
    is gone — while the config validation still refuses nonsense.  The
    full dropout semantics (no-leak, rejoin, conservation) run at
    trainer scale in tests/test_cohorts.py."""
    from repro.fl import CohortConfig, CohortScheduler

    class _FakeEngine:
        n_nodes = 4

    class _FakeTrainer:
        engine = _FakeEngine()

    sched = CohortScheduler(_FakeTrainer(), LognormalLatency(dropout=0.3))
    assert sched.latency.dropout == 0.3
    with pytest.raises(ValueError):
        CohortConfig(buffer_cohorts=0)
    with pytest.raises(ValueError):
        CohortConfig(staleness_policy="bogus")


# ----------------------------------------------------------------------
# Drain-phase staleness accounting (the satellite fix)
# ----------------------------------------------------------------------


def test_drain_staleness_advances_per_chunk(fl_problem):
    """Drain chunks are dispatch-free server steps: the effective round
    index keeps advancing, so jobs landing after the last round carry
    their real staleness.  With max_staleness=0 and a fleet whose every
    job lands long after the run, exactly ONE commit (round 0's own,
    s=0) survives — the pre-fix code stamped all drained arrivals with
    the last round index and wrongly committed the final round's jobs
    as fresh."""
    lat = ConstantLatency(compute_s=1000.0)
    _, res = _run_async(fl_problem, _cfg("mvr"),
                        AsyncConfig(buffer_size=1, max_staleness=0),
                        lat, rounds=2)
    arrivals = sum(1 for e in res.event_log if e[2] == ARRIVAL)
    assert int(res.committed.sum()) == 1
    assert res.staleness_hist == {0: 1}
    assert res.discarded_stale == arrivals - 1
    # drain rows (beyond the 2 in-loop rounds) committed nothing
    assert int(res.committed[2:].sum()) == 0


# ----------------------------------------------------------------------
# Staleness policies (power law + delay-adaptive) and Poisson windows
# ----------------------------------------------------------------------


def test_staleness_policy_registry_and_weights():
    p = make_staleness("power", exponent=0.5)
    assert isinstance(p, PowerLawStaleness)
    assert p.weight(0) == 1.0
    assert p.weight(3) == pytest.approx(4.0 ** -0.5)
    a = make_staleness("adaptive", exponent=0.5)
    assert isinstance(a, AdaptiveStaleness)
    assert a.weight(0) == 1.0
    # before any observation, adaptive == power law
    assert a.weight(3) == pytest.approx(4.0 ** -0.5)
    for s in (4, 4, 4):
        a.observe(s)
    # recentred: typical staleness is no longer discounted...
    assert a.mean_observed == pytest.approx(4.0)
    assert a.weight(4) == pytest.approx(1.0)
    # ...weights are clipped at 1 and still decay beyond the mean
    assert a.weight(1) == 1.0
    assert 0.0 < a.weight(20) < a.weight(8) < 1.0
    with pytest.raises(ValueError):
        make_staleness("bogus")


def test_adaptive_policy_sync_limit_parity(fl_problem):
    """Zero jitter ⇒ every commit has s=0 ⇒ adaptive weights are
    identically 1: the §9 parity contract holds under the new policy."""
    st_ref = _run_sync(fl_problem, _cfg("mvr"))
    st, res = _run_async(fl_problem, _cfg("mvr"),
                         AsyncConfig(buffer_size=3,
                                     staleness_policy="adaptive"),
                         ConstantLatency())
    np.testing.assert_allclose(np.asarray(st_ref.x), np.asarray(st.x),
                               rtol=1e-4, atol=1e-6)
    assert set(res.staleness_hist) == {0}


def test_adaptive_policy_replay_determinism_and_effect(fl_problem):
    """The stateful adaptive policy stays replay-deterministic (a fresh
    instance per run), and under heterogeneity it actually changes the
    trajectory vs the fixed power law."""
    lat = LognormalLatency(sigma=1.2, client_sigma=1.2, seed=3)
    acfg = AsyncConfig(buffer_size=1, staleness_policy="adaptive")
    (s1, r1), (s2, r2) = [
        _run_async(fl_problem, _cfg("mvr"), acfg, lat, rounds=25)
        for _ in range(2)]
    assert r1.event_log == r2.event_log
    np.testing.assert_array_equal(np.asarray(s1.x), np.asarray(s2.x))
    s_pow, r_pow = _run_async(fl_problem, _cfg("mvr"),
                              AsyncConfig(buffer_size=1), lat, rounds=25)
    assert r_pow.event_log == r1.event_log   # schedule is policy-free
    assert any(s > 0 for s in r1.staleness_hist)
    assert not np.allclose(np.asarray(s1.x), np.asarray(s_pow.x))


def test_poisson_availability_windows():
    av = PoissonAvailability(rate=0.5, off_mean=2.0, seed=1)
    av2 = PoissonAvailability(rate=0.5, off_mean=2.0, seed=1)
    ts = np.linspace(0.0, 50.0, 201)
    masks = np.asarray([av.mask(6, t) for t in ts])
    masks2 = np.asarray([av2.mask(6, t) for t in ts])
    np.testing.assert_array_equal(masks, masks2)       # deterministic
    assert not masks.all() and masks.any()             # windows both ways
    # querying out of order replays identically (lazy extension safety)
    av3 = PoissonAvailability(rate=0.5, off_mean=2.0, seed=1)
    rev = np.asarray([av3.mask(6, t) for t in ts[::-1]])[::-1]
    np.testing.assert_array_equal(masks, rev)
    # rate=0 is the always-available identity
    assert PoissonAvailability(rate=0.0).mask(4, 123.0).all()
    with pytest.raises(ValueError):
        PoissonAvailability(rate=-1.0)


def test_server_with_poisson_availability(fl_problem):
    """Sampled-but-offline clients skip the round (traced), dispatch
    conservation still holds, and the run stays finite."""
    av = PoissonAvailability(rate=0.4, off_mean=3.0, seed=2)
    srv = AsyncDashaServer(fl_problem, RandK(k=4), SNice(n=N, s=3),
                           _cfg("mvr"), AsyncConfig(buffer_size=2),
                           LognormalLatency(sigma=0.5, client_sigma=0.5,
                                            seed=1),
                           availability=av)
    st, res = srv.run(jax.random.key(4), jnp.zeros(D), 40)
    assert res.skipped_offline.sum() > 0
    assert res.committed.sum() == res.participants.sum()
    assert np.all(np.isfinite(res.loss))
    assert np.all(np.isfinite(np.asarray(st.x)))
