"""Compressor bit-accounting property tests (satellite of the async
PR): the ``wire_bits`` formulas of ``Composed``, ``TopK`` and
``RandomDithering`` must agree with the *measured* payload an actual
compression produces, and the sharded engine's ``NodeUpdateMetrics.
bits_sent`` must stay aggregation-aware for the new wire formats."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st   # hypothesis or deterministic fallback

from repro.core import variants
from repro.core.compressors import (Composed, NaturalCompression, RandK,
                                    RandomDithering, TopK, _index_bits)

_FLOAT = 32


# ----------------------------------------------------------------------
# wire_bits == measured payload
# ----------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(d=st.integers(4, 256), k=st.integers(1, 64), seed=st.integers(0, 99))
def test_topk_wire_bits_match_measured_payload(d, k, seed):
    """TopK sends exactly its sparse payload: keff float values plus
    keff coordinate indices at ceil(log2 d) bits."""
    comp = TopK(k=k)
    x = jax.random.normal(jax.random.key(seed), (d,))
    vals, idx = comp.compress_sparse(jax.random.key(seed + 1), x)
    keff = min(k, d)
    assert vals.shape == (keff,) and idx.shape == (keff,)
    measured = vals.size * _FLOAT + idx.size * _index_bits(d)
    assert comp.wire_bits(d) == measured


@settings(max_examples=25, deadline=None)
@given(d=st.integers(8, 256), k=st.integers(1, 64), seed=st.integers(0, 99))
def test_composed_wire_bits_match_measured_payload(d, k, seed):
    """Composed(RandK, Natural): keff indices + keff natural-compressed
    values at 9 bits each — the sparse payload it actually emits."""
    comp = Composed(inner=RandK(k=k), outer=NaturalCompression())
    x = jax.random.normal(jax.random.key(seed), (d,)) + 0.1
    vals, idx = comp.compress_sparse(jax.random.key(seed + 1), x)
    keff = min(k, d)
    assert vals.shape == (keff,) and idx.shape == (keff,)
    measured = idx.size * _index_bits(d) + vals.size * 9.0
    assert comp.wire_bits(d) == measured
    # and the values really are natural-compressed (powers of two times
    # sign — exponent+sign is all that crosses the wire)
    nz = np.asarray(vals)[np.asarray(vals) != 0]
    exps = np.log2(np.abs(nz))
    np.testing.assert_allclose(exps, np.round(exps), atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(d=st.integers(4, 256), s=st.integers(1, 15), seed=st.integers(0, 99))
def test_dithering_wire_bits_match_measured_payload(d, s, seed):
    """RandomDithering sends one norm float plus (sign + level) per
    coordinate; the output must decode from exactly that: at most s+1
    distinct levels of |x|/||x||, i.e. ceil(log2(s+1)) level bits."""
    comp = RandomDithering(s=s)
    x = jax.random.normal(jax.random.key(seed), (d,))
    out = np.asarray(comp.compress(jax.random.key(seed + 1), x))
    norm = float(jnp.linalg.norm(x))
    levels = np.unique(np.round(np.abs(out) / norm * s, 6))
    assert len(levels) <= s + 1
    level_bits = math.ceil(math.log2(s + 1))
    assert comp.wire_bits(d) == _FLOAT + d * (1 + level_bits)


# ----------------------------------------------------------------------
# rule-layer message_bits for the sharded wire formats
# ----------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(d=st.integers(64, 4096), ratio=st.floats(0.01, 0.5))
def test_message_bits_wire_formats(d, ratio):
    kw = dict(aggregation="sparse_allgather", compression_ratio=ratio,
              block_size=32)
    dense = variants.message_bits(d, aggregation="dense_psum",
                                  compression_ratio=ratio, block_size=32)
    topk = variants.message_bits(d, wire_format="topk", **kw)
    blk = variants.message_bits(d, wire_format="block_randk", **kw)
    dith = variants.message_bits(d, wire_format="dithering",
                                 dithering_levels=4, **kw)
    assert dense == d * 32.0
    k = max(1, math.ceil(ratio * d))
    assert topk == k * (32.0 + 32.0)
    bs, _, kb = variants.block_plan(d, 32, ratio)
    assert blk == kb * (bs * 32.0 + 32.0)
    # dithering: ratio-independent, (1 + ceil(log2 5)) = 4 bits/coord
    assert dith == 32.0 + 4.0 * d
    assert dith == variants.message_bits(
        d, wire_format="dithering", aggregation="sparse_allgather",
        compression_ratio=0.9, block_size=32)
    for bits in (topk, blk, dith):
        assert bits < dense


def test_sharded_config_validates_wire_format():
    from repro.core.sharded import ShardedDashaConfig
    base = dict(gamma=0.1, a=0.1, b=0.1)
    with pytest.raises(ValueError):
        ShardedDashaConfig(wire_format="bogus", **base)
    with pytest.raises(ValueError):
        ShardedDashaConfig(wire_format="topk", aggregation="dense_psum",
                           **base)
    with pytest.raises(ValueError):
        # ratio None is the dense baseline — it would silently bypass
        # the requested wire format
        ShardedDashaConfig(wire_format="dithering",
                           compression_ratio=None, **base)
    ShardedDashaConfig(wire_format="dithering", **base)   # ok


# ----------------------------------------------------------------------
# NodeUpdateMetrics.bits_sent stays aggregation-aware per wire format
# (single-device mesh: runs in-process)
# ----------------------------------------------------------------------


@pytest.mark.parametrize("wire,expect", [
    ("block_randk", None),      # expectation computed from block_plan
    ("topk", None),
    ("dithering", None),
])
def test_node_update_bits_sent_new_wire_formats(wire, expect):
    from jax.sharding import PartitionSpec as P
    from repro.compat import make_mesh, use_mesh
    from repro.core.sharded import ShardedDasha, ShardedDashaConfig
    d, bs, ratio = 96, 8, 0.25
    mesh = make_mesh((1,), ("data",))
    cfg = ShardedDashaConfig(gamma=0.1, a=0.1, b=0.3, p_a=1.0,
                             sampler="full", compression_ratio=ratio,
                             block_size=bs, data_axes=("data",),
                             wire_format=wire, dithering_levels=4)
    eng = ShardedDasha(mesh, {"w": P()}, cfg)
    g0 = {"w": jnp.ones((1, d))}
    with use_mesh(mesh):
        st = eng.init(g0)
        st, met = eng.node_update(g0, g0, st, jax.random.key(0))
    per_node = variants.message_bits(
        d, aggregation="sparse_allgather", compression_ratio=ratio,
        block_size=bs, wire_format=wire, dithering_levels=4)
    assert float(met.participants) == 1.0
    assert float(met.bits_sent) == per_node
    assert eng.uplink_bits_per_round(d) == per_node   # p_a = 1
    # dense_psum with the default wire still reports dense bits
    if wire == "block_randk":
        dense_cfg = ShardedDashaConfig(
            gamma=0.1, a=0.1, b=0.3, p_a=1.0, sampler="full",
            compression_ratio=ratio, block_size=bs,
            aggregation="dense_psum", data_axes=("data",))
        dense_eng = ShardedDasha(mesh, {"w": P()}, dense_cfg)
        assert dense_eng.uplink_bits_per_round(d) == d * 32.0
