"""Chunked prefill, prompt-length bucketing, and the fused batched
kernels (DESIGN.md §11).

tests/test_paged_engine.py anchors the engine's DEFAULT configuration
against the dense DecodeServer; this file stresses the prefill paths
specifically:

* tiny explicit chunk budgets force every prompt through MULTIPLE fused
  passes (the chunk accounting, the drop-routed page writes past
  ``q_lens``, and the mid-prompt ``start`` offsets all get exercised),
  and the greedy outputs must still equal the dense server's;
* preemption landing on a slot that is still ingesting its prompt must
  requeue it with nothing registered and reproduce the uncontended run;
* bulk-mode prompt-length bucketing must compile once per BUCKET (not
  once per distinct length) while the padded prefill stays greedy-
  equivalent to the exact-length one;
* TTFT is stamped at the pass that EMITS the first logit — never at
  admission (the chunked-prefill regression this PR fixes);
* the fused batched GQA kernel and the absorbed MLA kernel match their
  jnp oracles on random page tables, chunk widths, and windows.
"""
import jax
import jax.numpy as jnp
import math
import numpy as np
import pytest
from _hypo import given, settings, st   # hypothesis or deterministic fallback

from repro.kernels.ops import paged_attention_batched_op, paged_mla_attention_op
from repro.kernels.paged_attention import (paged_attention_batched_ref,
                                           paged_mla_attention_ref)
from repro.models import Model, get_smoke_config
from repro.models.model import PagedDecodeState
from repro.serving import DecodeServer, PagedEngine, Request


def _model(arch="granite-3-2b"):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


def _requests(cfg, n, seed=0, new=6, lo=2, hi=9):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(lo, hi))).tolist(),
                    max_new_tokens=new)
            for i in range(n)]


def _assert_token_parity(a, b):
    for ra, rb in zip(a, b):
        assert ra.generated == rb.generated, (ra.uid, ra.generated,
                                              rb.generated)


# ----------------------------------------------------------------------
# chunked prefill: multi-pass prompt ingestion keeps dense parity
# ----------------------------------------------------------------------

@pytest.mark.parametrize("arch,use_kernel",
                         [("granite-3-2b", False), ("granite-3-2b", True),
                          ("deepseek-v2-lite-16b", True)])
def test_small_chunk_parity(arch, use_kernel):
    """chunk=3 with prompts up to 8 tokens: every prompt needs several
    fused passes (mid-prompt ``start`` offsets, variable ``q_lens``
    per slot, pages crossed mid-chunk) and the greedy outputs still
    equal the dense server token-for-token."""
    cfg, model, params = _model(arch)
    dense = DecodeServer(model, params, batch_size=2, max_seq_len=32)
    d = dense.run(_requests(cfg, 5, lo=4, hi=9))
    paged = PagedEngine(model, params, batch_size=2, max_seq_len=32,
                        page_size=4, use_kernel=use_kernel,
                        prefill_chunk_tokens=3)
    p = paged.run(_requests(cfg, 5, lo=4, hi=9))
    _assert_token_parity(d, p)
    # the chunk budget actually split prompts: at least one request took
    # more than one ingestion pass, and prompt tokens rode fused passes
    # that also advanced decodes
    assert any(s.prefill_calls > 1 for s in paged.stats.values())
    assert paged.mixed_passes >= 1


def test_chunked_matches_bulk_prefill():
    """Chunked and bulk ingestion are different schedules over the same
    math: identical greedy outputs, and the default chunk folds the
    whole workload into no more prompt-ingesting passes than bulk's
    one-forward-per-admission."""
    cfg, model, params = _model()
    bulk = PagedEngine(model, params, batch_size=3, max_seq_len=32,
                       page_size=4, prefill_chunk_tokens=0)
    b = bulk.run(_requests(cfg, 6, seed=3))
    chunked = PagedEngine(model, params, batch_size=3, max_seq_len=32,
                          page_size=4)
    c = chunked.run(_requests(cfg, 6, seed=3))
    _assert_token_parity(b, c)
    assert bulk.prefill_forwards == 6       # one per admission
    assert 0 < chunked.prefill_forwards <= bulk.prefill_forwards


def test_preemption_mid_chunked_prefill():
    """Pool exhaustion while a slot is still ingesting its prompt: the
    victim requeues with nothing registered (its partially-written
    pages just vanish) and the greedy outputs still equal an
    uncontended reference run."""
    cfg, model, params = _model()
    # chunk=1 + 10..12-token prompts: ingestion takes ~11 passes, so the
    # second admission is still feeding when the first crosses a page
    # boundary into a dry 7-page pool (3+3 prompt pages + 1 decode page)
    reqs = _requests(cfg, 6, seed=1, new=8, lo=10, hi=13)
    reference = PagedEngine(model, params, batch_size=3, max_seq_len=32,
                            page_size=4, prefill_chunk_tokens=1)
    ref = reference.run([Request(uid=r.uid, prompt=list(r.prompt),
                                 max_new_tokens=r.max_new_tokens)
                         for r in reqs])
    assert reference.mid_prefill_preemptions == 0

    tight = PagedEngine(model, params, batch_size=3, max_seq_len=32,
                        page_size=4, num_pages=7, prefill_chunk_tokens=1)
    out = tight.run(reqs)
    assert tight.mid_prefill_preemptions >= 1
    assert all(len(r.generated) == 8 for r in out)
    _assert_token_parity(ref, out)
    tight.pool.check_invariants()


def test_ctor_rejects_recurrent_archs():
    """Chunk tails and bucket padding hide behind the causal mask;
    recurrent scans have none, so explicit opt-in raises instead of
    silently corrupting state — and the auto defaults fall back to
    bulk exact-length prefill."""
    cfg, model, params = _model("xlstm-350m")
    with pytest.raises(ValueError):
        PagedEngine(model, params, batch_size=2, max_seq_len=16,
                    page_size=4, prefill_chunk_tokens=4)
    with pytest.raises(ValueError):
        PagedEngine(model, params, batch_size=2, max_seq_len=16,
                    page_size=4, bucket_sizes=[8, 16])
    eng = PagedEngine(model, params, batch_size=2, max_seq_len=16,
                      page_size=4)
    assert eng.chunk == 0 and eng.bucket_sizes == []
    # the fused step itself refuses a multi-query pass on recurrent state
    state = PagedDecodeState(caches=eng._caches,
                             page_table=jnp.asarray(eng._table),
                             seq_lens=jnp.asarray(eng._lens))
    with pytest.raises(ValueError):
        model.paged_fused_step(params, jnp.zeros((2, 2), jnp.int32),
                               state, jnp.ones((2,), jnp.int32))


# ----------------------------------------------------------------------
# prompt-length bucketing (bulk mode)
# ----------------------------------------------------------------------

def test_bucketed_prefill_compiles_once_per_bucket():
    """Distinct prompt lengths inside one bucket reuse the SAME jit
    program (the recompile tax this PR removes); only crossing into a
    new bucket adds a compile."""
    cfg, model, params = _model()
    eng = PagedEngine(model, params, batch_size=2, max_seq_len=32,
                      page_size=4, prefill_chunk_tokens=0,
                      bucket_sizes=[8, 16])
    reqs = [Request(uid=i, prompt=[3 + i] * (3 + i), max_new_tokens=2)
            for i in range(5)]             # lengths 3..7: one bucket (8)
    eng.run(reqs)
    assert eng.prefill_cache_size() == 1
    eng.run([Request(uid=10, prompt=[7] * 10, max_new_tokens=2)])
    assert eng.prefill_cache_size() == 2   # length 10 -> bucket 16
    eng.run([Request(uid=11, prompt=[2] * 12, max_new_tokens=2)])
    assert eng.prefill_cache_size() == 2   # length 12: bucket 16 again


def test_padded_prefill_greedy_parity():
    """Bucket padding is drop-routed (``true_len`` gates the page
    writes, the head reads the hidden state at the true last token):
    padded and exact-length prefill produce the same greedy tokens and
    numerically-equal decode logits."""
    cfg, model, params = _model()
    exact = PagedEngine(model, params, batch_size=2, max_seq_len=32,
                        page_size=4, prefill_chunk_tokens=0,
                        bucket_sizes=[], trace_logits=True)
    e = exact.run(_requests(cfg, 5, seed=2))
    padded = PagedEngine(model, params, batch_size=2, max_seq_len=32,
                         page_size=4, prefill_chunk_tokens=0,
                         trace_logits=True)
    p = padded.run(_requests(cfg, 5, seed=2))
    _assert_token_parity(e, p)
    for uid in exact.logit_trace:
        # padded prefill reduces in a different shape than exact-length,
        # so allclose (not bitwise) is the contract here
        np.testing.assert_allclose(np.stack(exact.logit_trace[uid]),
                                   np.stack(padded.logit_trace[uid]),
                                   rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# TTFT accounting
# ----------------------------------------------------------------------

def test_ttft_stamped_at_first_logit_not_admission():
    """A length-7 prompt under chunk=2 needs 4 ingestion passes; the
    first logit exists only after the last of them.  Stamping at
    admission (the bug this PR fixes) would report ttft=0."""
    cfg, model, params = _model()
    eng = PagedEngine(model, params, batch_size=1, max_seq_len=32,
                      page_size=4, prefill_chunk_tokens=2)
    req = Request(uid=0, prompt=[5, 9, 3, 7, 2, 8, 4], max_new_tokens=3)
    eng.run([req])
    st_ = eng.stats[0]
    assert st_.admitted_at == 0
    assert st_.first_token_at == 4         # ceil(7 / 2) ingestion passes
    assert st_.ttft == 4

    # bulk mode: the single prefill forward emits the logit -> ttft 1
    bulk = PagedEngine(model, params, batch_size=1, max_seq_len=32,
                       page_size=4, prefill_chunk_tokens=0)
    bulk.run([Request(uid=0, prompt=[5, 9, 3, 7, 2, 8, 4],
                      max_new_tokens=3)])
    assert bulk.stats[0].ttft == 1


def test_ttft_percentiles_reflect_queueing():
    """Requests beyond the batch wait in the queue; their TTFT includes
    the wait, so p95 > p50 on an oversubscribed workload and every
    chunked TTFT is at least the ingestion-pass lower bound."""
    cfg, model, params = _model()
    eng = PagedEngine(model, params, batch_size=2, max_seq_len=32,
                      page_size=4, prefill_chunk_tokens=2)
    eng.run(_requests(cfg, 6, seed=4, new=6, lo=5, hi=9))
    for st_ in eng.stats.values():
        assert st_.first_token_at is not None
        assert st_.first_token_at > st_.admitted_at
    m = eng.metrics()
    assert m["ttft_p95"] >= m["ttft_p50"] > 0


# ----------------------------------------------------------------------
# fused batched kernels vs jnp oracles
# ----------------------------------------------------------------------

@settings(max_examples=6)
@given(seed=st.integers(0, 1000), windowed=st.booleans())
def test_batched_paged_attention_kernel_matches_ref(seed, windowed):
    """The multi-query GQA launch on random page tables, starts, and
    chunk widths.  Padding rows (c >= q_lens) compute the same
    position-(start+c) attention in kernel and oracle — the engine
    ignores them via drop-routed writes, so full-array comparison is
    valid here."""
    key = jax.random.key(seed)
    B, C, H, kvh, hd, P, NP, M = 2, 3, 4, 2, 8, 4, 16, 4
    mk = lambda i, s: jax.random.normal(jax.random.fold_in(key, i), s)
    q = mk(0, (B, C, H, hd))
    k = mk(1, (NP, P, kvh, hd))
    v = mk(2, (NP, P, kvh, hd))
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.permutation(NP)[:B * M].reshape(B, M), jnp.int32)
    start = jnp.asarray(rng.integers(0, M * P - C + 1, B), jnp.int32)
    q_lens = jnp.asarray(rng.integers(1, C + 1, B), jnp.int32)
    window = 5 if windowed else None
    ref = paged_attention_batched_ref(q, k, v, table, start, q_lens,
                                      window=window)
    out = paged_attention_batched_op(q, k, v, table, start, q_lens,
                                     window=window, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=6)
@given(seed=st.integers(0, 1000), windowed=st.booleans())
def test_paged_mla_kernel_matches_ref(seed, windowed):
    """The absorbed-form latent kernel: scores against the rank-r pages
    plus the rope rows, output accumulated in latent space."""
    key = jax.random.key(seed)
    B, C, H, r, rr, P, NP, M = 2, 3, 4, 8, 4, 4, 16, 4
    mk = lambda i, s: jax.random.normal(jax.random.fold_in(key, i), s)
    q_abs = mk(0, (B, C, H, r))
    q_rope = mk(1, (B, C, H, rr))
    ckv = mk(2, (NP, P, r))
    kr = mk(3, (NP, P, rr))
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.permutation(NP)[:B * M].reshape(B, M), jnp.int32)
    start = jnp.asarray(rng.integers(0, M * P - C + 1, B), jnp.int32)
    q_lens = jnp.asarray(rng.integers(1, C + 1, B), jnp.int32)
    window = 6 if windowed else None
    scale = 1.0 / math.sqrt(12.0)
    ref = paged_mla_attention_ref(q_abs, q_rope, ckv, kr, table, start,
                                  q_lens, scale=scale, window=window)
    out = paged_mla_attention_op(q_abs, q_rope, ckv, kr, table, start,
                                 q_lens, scale=scale, window=window,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
