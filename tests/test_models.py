"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned family — 2 layers, d_model <= 512, <= 4 experts — one forward /
train step on CPU asserting output shapes and no NaNs; plus decode
consistency and attention-path equivalences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ARCH_IDS, Model, count_params, get_smoke_config
from repro.models.layers import (attention_weights_mask,
                                 blockwise_gqa_attention, gqa_attention)

B, T = 2, 16


def _batch(cfg, key, t=T):
    if cfg.frontend == "audio":
        return {"embeds": jax.random.normal(key, (B, t, cfg.d_model),
                                            cfg.param_dtype),
                "targets": jax.random.randint(key, (B, t), 0,
                                              cfg.vocab_size)}
    if cfg.frontend == "vision":
        return {"embeds": jax.random.normal(
                    key, (B, cfg.frontend_tokens, cfg.d_model),
                    cfg.param_dtype),
                "tokens": jax.random.randint(key, (B, t), 0,
                                             cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (B, t), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one gradient step on the reduced config."""
    cfg = get_smoke_config(arch)
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = Model(cfg)
    key = jax.random.key(0)
    params = model.init_params(key)
    batch = _batch(cfg, jax.random.key(1))

    logits, aux = jax.jit(model.forward)(params, batch)
    t_expect = (T + cfg.frontend_tokens if cfg.frontend == "vision" else
                T)
    assert logits.shape == (B, t_expect, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert jnp.isfinite(loss)
    gn = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and float(gn) > 0
    # one SGD step still yields finite loss
    params2 = jax.tree.map(lambda p, g: p - 1e-2 * g.astype(p.dtype),
                           params, grads)
    loss2 = jax.jit(model.loss)(params2, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "hubert-xlarge"])
def test_smoke_decode_matches_forward(arch):
    """prefill -> one serve_step equals the (T+1)-token forward."""
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    last, state = jax.jit(
        lambda p, b: model.prefill(p, b, extra_capacity=4))(params, batch)
    assert last.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    logits, state2 = jax.jit(model.serve_step)(params, tok, state)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert int(state2.position) == int(state.position) + 1
    if cfg.frontend is None:
        batch2 = {"tokens": jnp.concatenate([batch["tokens"], tok], 1)}
        ref = model.forward(params, batch2)[0][:, -1, :cfg.vocab_size]
        err = float(jnp.max(jnp.abs(ref.astype(jnp.float32)
                                    - logits.astype(jnp.float32))))
        assert err < 5e-3, err


def test_encoder_has_no_decode():
    cfg = get_smoke_config("hubert-xlarge")
    assert cfg.is_encoder and not cfg.supports_decode


def test_long_context_variants():
    """for_long_context() enables SWA exactly for the full-attention
    archs and leaves SSM/hybrid untouched."""
    from repro.models import get_config
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        lc = cfg.for_long_context()
        if arch in ("xlstm-350m", "hymba-1.5b"):
            assert lc.attention_window == cfg.attention_window
        elif arch == "hubert-xlarge":
            pass
        else:
            assert lc.attention_window == 4096
            assert cfg.attention_window is None  # decode_32k keeps full KV


def test_blockwise_attention_matches_dense():
    key = jax.random.key(0)
    Bq, Tq, H, kvH, hd = 2, 200, 8, 2, 16
    q = jax.random.normal(key, (Bq, Tq, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (Bq, Tq, kvH, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (Bq, Tq, kvH, hd))
    pos = jnp.arange(Tq)
    for causal, window, prefix in [(True, None, 0), (True, 31, 0),
                                   (True, None, 13), (False, None, 0)]:
        mask = attention_weights_mask(pos, pos, causal, window,
                                      full_prefix=prefix)
        ref = gqa_attention(q, k, v, mask)
        out = blockwise_gqa_attention(q, k, v, pos, pos, causal=causal,
                                      window=window, full_prefix=prefix,
                                      q_block=48, k_block=64)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-5)


def test_moe_capacity_drops_bounded():
    """With capacity_factor=1.0 the dispatch keeps <= C tokens per expert
    and the layer still runs/normalizes."""
    import dataclasses
    cfg = get_smoke_config("dbrx-132b")
    cfg = cfg.with_overrides(moe=dataclasses.replace(cfg.moe,
                                                     capacity_factor=1.0))
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    loss = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss)


def test_vocab_padding_multiple_of_256():
    from repro.models import get_config
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab - cfg.vocab_size < 256
