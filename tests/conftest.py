"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real (1-device) CPU; only tests that need a host mesh spawn
it via the session-scoped ``host_mesh`` fixture below, which is skipped
unless the test session was started with REPRO_HOST_DEVICES set."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.key(0)


@pytest.fixture(scope="session")
def small_problem():
    from repro.core import LogisticSigmoidProblem, make_synthetic_classification
    feats, y = make_synthetic_classification(
        jax.random.key(0), n_nodes=10, m_per_node=8, d=24)
    return LogisticSigmoidProblem(feats, y)
