"""Buffered-async (FedBuff-style) conformance suite, for BOTH the flat
:class:`AsyncDashaServer` and the hierarchical fleet's tiers:

* exactly K commits per server step whenever K arrivals are available,
* staleness is stamped at COMMIT time, not arrival time,
* contributions past ``max_staleness`` are discarded whole (no tracker
  or estimator write from the discarded contribution at the discarding
  level),
* the drain replays deterministically under a fixed seed.
"""
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (LogisticSigmoidProblem, RandK, SNice,
                        make_synthetic_classification)
from repro.core.dasha_pp import DashaPP, DashaPPConfig
from repro.fl import (AsyncConfig, AsyncDashaServer, ConstantLatency,
                      DenseProblemWorkload, FleetConfig,
                      HierarchicalFleet, LognormalLatency, TierConfig)
from test_fleet import OneSlowClient

N, M, D = 6, 5, 16


@pytest.fixture(scope="module")
def problem():
    feats, y = make_synthetic_classification(jax.random.key(0),
                                             n_nodes=N, m_per_node=M, d=D)
    return LogisticSigmoidProblem(feats, y)


def _cfg(variant="gradient"):
    return DashaPPConfig(variant, gamma=0.02, a=0.1, b=0.3, p_page=0.4,
                         batch_size=2)


def _server(problem, *, s=N, latency, **acfg):
    return AsyncDashaServer(problem, RandK(k=4), SNice(n=N, s=s),
                            _cfg(), AsyncConfig(**acfg), latency)


# ======================================================================
# Flat server
# ======================================================================

def test_server_exactly_k_commits_per_step(problem):
    """With K arrivals available the server commits exactly K — never
    more — and every dispatched contribution is eventually committed
    (no staleness cap, no dropout)."""
    srv = _server(problem, s=4, buffer_size=2,
                  latency=LognormalLatency(compute_s=1.0, sigma=0.8,
                                           client_sigma=0.8, seed=5))
    _, res = srv.run(jax.random.key(9), jnp.zeros(D), 10)
    assert res.committed.max() == 2
    assert np.all(res.committed <= 2)
    assert int(res.committed.sum()) == int(res.participants.sum())
    assert res.discarded_stale == 0 and res.dropped == 0


def test_server_staleness_stamped_at_commit_not_arrival(problem):
    """Full participation, zero jitter, K=1: ALL round-0 jobs arrive
    physically at t=1.0, but the K=1 buffer commits them one server
    step at a time — so their recorded staleness is 0,1,2,… (the
    commit round minus the dispatch round), not the 0 an arrival-time
    stamp would give every one of them."""
    srv = _server(problem, buffer_size=1,
                  latency=ConstantLatency(compute_s=1.0))
    _, res = srv.run(jax.random.key(9), jnp.zeros(D), 4)
    # rounds 0-3 commit one round-0 job each (s = 0,1,2,3); the drain
    # commits the remaining two round-0 jobs (s = 4,5) and the three
    # re-dispatched jobs, each 5 rounds stale by the time its turn comes.
    assert res.staleness_hist == {0: 1, 1: 1, 2: 1, 3: 1, 4: 1, 5: 4}
    np.testing.assert_array_equal(res.staleness_max[:4], [0, 1, 2, 3])
    assert np.all(res.committed == 1)


def test_server_late_arrivals_discarded_whole(problem):
    """max_staleness=0 with arrivals landing after the run: only the
    single round-0 commit survives; every discarded contribution is
    discarded WHOLE — its h_i and g_i rows still equal init exactly."""
    eng = DashaPP(problem, RandK(k=4), SNice(n=N, s=N), _cfg())
    st0 = eng.init(jax.random.split(jax.random.key(9))[0], jnp.zeros(D))
    srv = _server(problem, buffer_size=1, max_staleness=0,
                  latency=ConstantLatency(compute_s=1000.0))
    state, res = srv.run(jax.random.key(9), jnp.zeros(D), 3)
    assert int(res.committed.sum()) == 1
    assert res.discarded_stale == int(res.participants.sum()) - 1
    # the lone survivor is client 0 (first dispatched, first popped)
    h_i, g_i = np.asarray(state.h_i), np.asarray(state.g_i)
    h0, g0 = np.asarray(st0.h_i), np.asarray(st0.g_i)
    np.testing.assert_array_equal(h_i[1:], h0[1:])
    np.testing.assert_array_equal(g_i[1:], g0[1:])
    assert not np.array_equal(h_i[0], h0[0])   # the survivor DID land


def test_server_deterministic_drain_order(problem):
    """Same seed ⇒ identical popped-event log, identical staleness
    histogram, bitwise-identical final iterate."""
    def go():
        srv = _server(problem, s=4, buffer_size=3, max_staleness=4,
                      latency=LognormalLatency(compute_s=1.0, sigma=1.0,
                                               client_sigma=1.0, seed=2))
        return srv.run(jax.random.key(5), jnp.zeros(D), 8)
    s1, r1 = go()
    s2, r2 = go()
    assert r1.event_log == r2.event_log and len(r1.event_log) > 0
    assert r1.staleness_hist == r2.staleness_hist
    np.testing.assert_array_equal(np.asarray(s1.x), np.asarray(s2.x))
    np.testing.assert_array_equal(r1.committed, r2.committed)


# ======================================================================
# Tree tiers
# ======================================================================

def _wl(problem, s=N):
    return DenseProblemWorkload(problem, RandK(k=4), SNice(n=N, s=s),
                                _cfg())


def test_tier_flushes_exactly_k_members(problem):
    """A K-buffered edge flushes messages of exactly K members; only
    the explicit timeout path (``forced=True``) may go under."""
    fleet = HierarchicalFleet(
        _wl(problem),
        FleetConfig(tiers=(TierConfig(aggregators=2, buffer_size=2),)),
        ConstantLatency(compute_s=1.0))
    _, res = fleet.run(jax.random.key(9), jnp.zeros(D), 6)
    natural = [m for m in res.message_log if not m.forced]
    assert natural and all(m.n_members == 2 for m in natural)
    assert all(m.n_members < 2 for m in res.message_log if m.forced)
    assert set(res.flush_sizes[0]) <= {1, 2}
    assert int(res.committed.sum()) == int(res.participants.sum())


def test_tier_staleness_stamped_at_root_commit(problem):
    """Every commit record's staleness equals commit round minus
    dispatch round, its hop stamps are sandwiched between the two and
    non-decreasing, the histogram is exactly the commit log's, and the
    K_root-buffered root applies at most K_root messages per step."""
    fleet = HierarchicalFleet(
        _wl(problem, s=3),
        FleetConfig(tiers=(TierConfig(aggregators=2, buffer_size=1),),
                    buffer_size=2),
        LognormalLatency(compute_s=1.0, sigma=0.8, client_sigma=0.8,
                         seed=5))
    _, res = fleet.run(jax.random.key(9), jnp.zeros(D), 10)
    assert res.commit_log
    for rec in res.commit_log:
        assert rec.staleness == rec.commit_round - rec.dispatch_round
        stamps = [r for _, r in rec.hops]
        assert stamps == sorted(stamps)
        assert all(rec.dispatch_round <= r <= rec.commit_round
                   for r in stamps)
    assert Counter(r.staleness for r in res.commit_log) \
        == res.staleness_hist
    assert any(r.staleness > 0 for r in res.commit_log)
    assert res.committed_msgs.max() == 2
    assert np.all(res.committed_msgs <= 2)


def test_root_discard_keeps_edge_tracker_write(problem):
    """The root-level max_staleness discard happens ABOVE the edge: the
    straggler's h_i row was already (correctly) written at its edge
    flush, but nothing of it reaches g_i/g — the documented two-level
    discard semantics (fl/tree.py docstring)."""
    eng = DashaPP(problem, RandK(k=4), SNice(n=N, s=N), _cfg())
    st0 = eng.init(jax.random.split(jax.random.key(7))[0], jnp.zeros(D))
    fleet = HierarchicalFleet(
        _wl(problem),
        FleetConfig(tiers=(TierConfig(aggregators=2, buffer_size=1),),
                    buffer_size=3, max_staleness=2),
        OneSlowClient(compute_s=1.0, slow_client=0, slow_s=100.0))
    fs, res = fleet.run(jax.random.key(7), jnp.zeros(D), 5)
    assert res.discarded_stale >= 1
    assert all(rec.client != 0 for rec in res.commit_log)
    # h WAS written (edge owns the shard) ...
    assert not np.array_equal(fs.store.gather("h_i", [0])[0],
                              np.asarray(st0.h_i)[0])
    # ... but the root excluded it from the estimator state entirely
    np.testing.assert_array_equal(fs.store.gather("g_i", [0])[0],
                                  np.asarray(st0.g_i)[0])
    assert int(res.committed.sum()) + res.discarded_stale \
        == int(res.participants.sum())
