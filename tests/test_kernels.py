"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ops import (block_gather_op, block_scatter_op,
                               dasha_update_op)


@pytest.mark.parametrize("d", [1, 7, 128, 1000, 128 * 512, 128 * 512 + 17,
                               1 << 18])
@pytest.mark.parametrize("part", [0.0, 1.0])
def test_dasha_update_shapes(d, part):
    key = jax.random.key(d)
    gn, go, h, gi = (jax.random.normal(jax.random.fold_in(key, i), (d,))
                     for i in range(4))
    args = dict(b=0.25, a=0.04, pa=0.5, participates=jnp.asarray(part))
    outs = dasha_update_op(gn, go, h, gi, **args)
    refs = ref.dasha_update_ref(gn, go, h, gi, **args)
    for o, r in zip(outs, refs):
        assert o.shape == (d,)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(b=st.floats(0.0, 1.0), a=st.floats(0.0, 1.0),
       pa=st.floats(0.05, 1.0), seed=st.integers(0, 50))
def test_dasha_update_hyperparam_sweep(b, a, pa, seed):
    d = 513
    key = jax.random.key(seed)
    gn, go, h, gi = (jax.random.normal(jax.random.fold_in(key, i), (d,))
                     for i in range(4))
    args = dict(b=b, a=a, pa=pa, participates=jnp.asarray(1.0))
    outs = dasha_update_op(gn, go, h, gi, **args)
    refs = ref.dasha_update_ref(gn, go, h, gi, **args)
    for o, r in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=2e-5, atol=1e-5)


def test_dasha_update_participation_freezes_h():
    d = 256
    key = jax.random.key(0)
    gn, go, h, gi = (jax.random.normal(jax.random.fold_in(key, i), (d,))
                     for i in range(4))
    _, h_new, _ = dasha_update_op(gn, go, h, gi, b=0.3, a=0.1, pa=0.25,
                                  participates=jnp.asarray(0.0))
    np.testing.assert_allclose(np.asarray(h_new), np.asarray(h))


@pytest.mark.parametrize("nb,bs,kb", [(8, 128, 1), (64, 128, 7),
                                      (32, 8, 32), (100, 128, 50)])
def test_block_gather(nb, bs, kb):
    key = jax.random.key(nb * bs)
    x = jax.random.normal(key, (nb, bs))
    idx = jnp.asarray(
        np.random.default_rng(0).choice(nb, kb, replace=False), jnp.int32)
    scale = nb / kb
    out = block_gather_op(x, idx, scale=scale)
    want = ref.block_gather_ref(x, idx, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("nb,bs,kb", [(8, 128, 3), (64, 64, 17)])
def test_block_scatter(nb, bs, kb):
    rng = np.random.default_rng(1)
    base = jnp.asarray(rng.standard_normal((nb, bs)), jnp.float32)
    vals = jnp.asarray(rng.standard_normal((kb, bs)), jnp.float32)
    idx = jnp.asarray(rng.choice(nb, kb, replace=False), jnp.int32)
    out = block_scatter_op(base, vals, idx)
    want = ref.block_scatter_add_ref(base, vals, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


def test_gather_scatter_roundtrip_unbiased():
    """BlockRandK as used by the sharded engine: gather-then-scatter of a
    zero base reproduces the dense BlockRandK output, and averaging over
    many keys approaches the identity (unbiasedness at block level)."""
    from repro.core.sharded import block_randk_dense
    d = 1024
    x = jax.random.normal(jax.random.key(0), (d,))
    keys = jax.random.split(jax.random.key(1), 600)
    outs = jax.vmap(lambda k: block_randk_dense(k, x, 4, 128))(keys)
    mean = jnp.mean(outs, axis=0)
    rel = float(jnp.linalg.norm(mean - x) / jnp.linalg.norm(x))
    assert rel < 0.15, rel
