"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st   # hypothesis or deterministic fallback

from repro.kernels import ref
from repro.kernels.ops import (block_gather_op, block_scatter_op,
                               dasha_h_update_op, dasha_page_h_update_op,
                               dasha_page_payload_blocks_op,
                               dasha_page_update_op,
                               dasha_payload_blocks_op, dasha_tail_op,
                               dasha_update_batched_op, dasha_update_op)


def _node_arrays(n, d, count, seed=0):
    key = jax.random.key(seed)
    return tuple(jax.random.normal(jax.random.fold_in(key, i), (n, d))
                 for i in range(count))


def _assert_all_close(outs, refs, rtol=1e-5, atol=1e-6):
    for o, r in zip(outs, refs):
        assert o.shape == r.shape
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("d", [1, 7, 128, 1000, 128 * 512, 128 * 512 + 17,
                               1 << 18])
@pytest.mark.parametrize("part", [0.0, 1.0])
def test_dasha_update_shapes(d, part):
    key = jax.random.key(d)
    gn, go, h, gi = (jax.random.normal(jax.random.fold_in(key, i), (d,))
                     for i in range(4))
    args = dict(b=0.25, a=0.04, pa=0.5, participates=jnp.asarray(part))
    outs = dasha_update_op(gn, go, h, gi, **args)
    refs = ref.dasha_update_ref(gn, go, h, gi, **args)
    for o, r in zip(outs, refs):
        assert o.shape == (d,)
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(b=st.floats(0.0, 1.0), a=st.floats(0.0, 1.0),
       pa=st.floats(0.05, 1.0), seed=st.integers(0, 50))
def test_dasha_update_hyperparam_sweep(b, a, pa, seed):
    d = 513
    key = jax.random.key(seed)
    gn, go, h, gi = (jax.random.normal(jax.random.fold_in(key, i), (d,))
                     for i in range(4))
    args = dict(b=b, a=a, pa=pa, participates=jnp.asarray(1.0))
    outs = dasha_update_op(gn, go, h, gi, **args)
    refs = ref.dasha_update_ref(gn, go, h, gi, **args)
    for o, r in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                   rtol=2e-5, atol=1e-5)


def test_dasha_update_participation_freezes_h():
    d = 256
    key = jax.random.key(0)
    gn, go, h, gi = (jax.random.normal(jax.random.fold_in(key, i), (d,))
                     for i in range(4))
    _, h_new, _ = dasha_update_op(gn, go, h, gi, b=0.3, a=0.1, pa=0.25,
                                  participates=jnp.asarray(0.0))
    np.testing.assert_allclose(np.asarray(h_new), np.asarray(h))


# ---------------------------------------------------------------------
# Batched (node-major) kernel family vs the jnp oracles
# ---------------------------------------------------------------------

@pytest.mark.parametrize("d", [1, 7, 129, 1000])   # odd d -> padding path
@pytest.mark.parametrize("mask", [[1, 1, 1], [0, 0, 0], [1, 0, 1]])
def test_batched_update_parity(d, mask):
    """p_a < 1 participation masks and the lane-padding path."""
    gn, go, h, gi = _node_arrays(3, d, 4, seed=d)
    m = jnp.asarray(mask, jnp.float32)
    args = dict(b=0.25, a=0.04, pa=0.5)
    outs = dasha_update_batched_op(gn, go, h, gi, m, **args)
    refs = ref.dasha_update_batched_ref(gn, go, h, gi, m, **args)
    _assert_all_close(outs, refs)


def test_batched_update_interpret_explicit():
    """interpret=True must be forceable regardless of backend default."""
    gn, go, h, gi = _node_arrays(2, 300, 4, seed=9)
    m = jnp.asarray([1.0, 0.0])
    args = dict(b=0.1, a=0.3, pa=0.25)
    outs = dasha_update_batched_op(gn, go, h, gi, m, interpret=True, **args)
    refs = ref.dasha_update_batched_ref(gn, go, h, gi, m, **args)
    _assert_all_close(outs, refs)


@pytest.mark.parametrize("coin", [0.0, 1.0])
@pytest.mark.parametrize("d", [5, 384, 1000])
def test_page_update_parity(coin, d):
    """Both PAGE branches of the fused Alg. 3 kernel."""
    gn, go, bn, bo, h, gi = _node_arrays(4, d, 6, seed=d + 1)
    m = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    c = jnp.asarray(coin)
    args = dict(b=0.25, a=0.04, pa=0.5, p_page=0.125)
    outs = dasha_page_update_op(gn, go, bn, bo, h, gi, m, c, **args)
    refs = ref.dasha_page_update_ref(gn, go, bn, bo, h, gi, m, c, **args)
    _assert_all_close(outs, refs)


@pytest.mark.parametrize("d", [3, 256, 777])
def test_tail_parity(d):
    """Lines 10-11 with an externally supplied k (finite-MVR path)."""
    k, h, gi = _node_arrays(5, d, 3, seed=d + 2)
    m = jnp.asarray([0.0, 1.0, 1.0, 0.0, 1.0])
    outs = dasha_tail_op(k, h, gi, m, a=0.07, pa=0.25)
    refs = ref.dasha_tail_ref(k, h, gi, m, a=0.07, pa=0.25)
    _assert_all_close(outs, refs)


@pytest.mark.parametrize("d,bs,kb", [(1024, 128, 2), (1000, 128, 3),
                                     (64, 8, 4), (129, 128, 1)])
def test_payload_blocks_fused_compress(d, bs, kb):
    """The fused update+compress must equal dense payload -> block gather
    (unbiasedness scale included), incl. ragged last block."""
    gn, go, h, gi = (jax.random.normal(jax.random.fold_in(jax.random.key(d), i),
                                       (d,)) for i in range(4))
    nb = -(-d // bs)
    idx = jnp.asarray(
        np.random.default_rng(d).choice(nb, kb, replace=False), jnp.int32)
    args = dict(b=0.3, a=0.05, pa=0.5, scale=nb / kb, block_size=bs)
    out = dasha_payload_blocks_op(gn, go, h, gi, idx, **args)
    want = ref.dasha_payload_blocks_ref(gn, go, h, gi, idx, **args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("part", [0.0, 1.0])
def test_h_update_parity(part):
    d = 513
    gn, go, h, gi = (jax.random.normal(jax.random.fold_in(jax.random.key(1), i),
                                       (d,)) for i in range(4))
    out = dasha_h_update_op(gn, go, h, b=0.2, pa=0.5,
                            participates=jnp.asarray(part))
    _, want, _ = ref.dasha_update_ref(gn, go, h, gi, b=0.2, a=0.0, pa=0.5,
                                      participates=jnp.asarray(part))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("coin", [0.0, 1.0])
@pytest.mark.parametrize("part", [0.0, 1.0])
def test_page_h_update_parity(coin, part):
    """Line 10 with the PAGE k recomputed in-register (both branches,
    both participation states)."""
    d = 513
    gn, go, bn, bo, h = (
        jax.random.normal(jax.random.fold_in(jax.random.key(3), i), (d,))
        for i in range(5))
    args = dict(b=0.2, pa=0.5, p_page=0.25)
    out = dasha_page_h_update_op(gn, go, bn, bo, h, jnp.asarray(coin),
                                 participates=jnp.asarray(part), **args)
    want = ref.dasha_page_h_update_ref(gn, go, bn, bo, h,
                                       jnp.asarray(part),
                                       jnp.asarray(coin), **args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("coin", [0.0, 1.0])
@pytest.mark.parametrize("d,bs,kb", [(1024, 128, 2), (1000, 128, 3),
                                     (64, 8, 4)])
def test_page_payload_blocks_fused_compress(coin, d, bs, kb):
    """The fused PAGE update+compress must equal dense PAGE payload ->
    block gather, on both coin branches (incl. ragged last block)."""
    gn, go, bn, bo, h, gi = (
        jax.random.normal(jax.random.fold_in(jax.random.key(d), i), (d,))
        for i in range(6))
    nb = -(-d // bs)
    idx = jnp.asarray(
        np.random.default_rng(d).choice(nb, kb, replace=False), jnp.int32)
    args = dict(b=0.3, a=0.05, pa=0.5, p_page=0.25, scale=nb / kb,
                block_size=bs)
    c = jnp.asarray(coin)
    out = dasha_page_payload_blocks_op(gn, go, bn, bo, h, gi, idx, c,
                                       **args)
    want = ref.dasha_page_payload_blocks_ref(gn, go, bn, bo, h, gi, idx,
                                             c, **args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("nb,bs,kb", [(8, 128, 1), (64, 128, 7),
                                      (32, 8, 32), (100, 128, 50)])
def test_block_gather(nb, bs, kb):
    key = jax.random.key(nb * bs)
    x = jax.random.normal(key, (nb, bs))
    idx = jnp.asarray(
        np.random.default_rng(0).choice(nb, kb, replace=False), jnp.int32)
    scale = nb / kb
    out = block_gather_op(x, idx, scale=scale)
    want = ref.block_gather_ref(x, idx, scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("nb,bs,kb", [(8, 128, 3), (64, 64, 17)])
def test_block_scatter(nb, bs, kb):
    rng = np.random.default_rng(1)
    base = jnp.asarray(rng.standard_normal((nb, bs)), jnp.float32)
    vals = jnp.asarray(rng.standard_normal((kb, bs)), jnp.float32)
    idx = jnp.asarray(rng.choice(nb, kb, replace=False), jnp.int32)
    out = block_scatter_op(base, vals, idx)
    want = ref.block_scatter_add_ref(base, vals, idx)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)


def test_gather_scatter_roundtrip_unbiased():
    """BlockRandK as used by the sharded engine: gather-then-scatter of a
    zero base reproduces the dense BlockRandK output, and averaging over
    many keys approaches the identity (unbiasedness at block level)."""
    from repro.core.sharded import block_randk_dense
    d = 1024
    x = jax.random.normal(jax.random.key(0), (d,))
    keys = jax.random.split(jax.random.key(1), 600)
    outs = jax.vmap(lambda k: block_randk_dense(k, x, 4, 128))(keys)
    mean = jnp.mean(outs, axis=0)
    rel = float(jnp.linalg.norm(mean - x) / jnp.linalg.norm(x))
    assert rel < 0.15, rel
