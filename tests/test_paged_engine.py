"""PagedEngine correctness (DESIGN.md §11).

The parity anchor: with ``page_size >= max_seq`` (one page per slot)
and greedy sampling, the paged engine must reproduce the dense
``DecodeServer.run`` token-for-token — attention and MLA archs, Pallas
kernel on and off.  Token ids ARE compared here (unlike
tests/test_serving.py's byte-level asserts) because both servers run in
the same process on the same params: the sequences are mathematically
identical greedy decodes and the seeds below produce decisive logit
gaps (bulk vs token-by-token prefill reduce in different shapes, so
bit-equality is not guaranteed, only argmax equality).

Beyond the anchor: shared-prefix pages produce BITWISE-identical decode
logits vs an unshared engine (same-length prompts compile to the same
prefill program, so the prefix KV bytes coincide exactly); pool
exhaustion preempts and re-admits without changing any greedy output;
and the paged-attention kernel matches its jnp oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st   # hypothesis or deterministic fallback

from repro.kernels.ops import paged_attention_op
from repro.kernels.paged_attention import (paged_attention_ref,
                                           paged_attention_vmem_bytes)
from repro.models import Model, get_smoke_config
from repro.serving import DecodeServer, PagedEngine, Request


def _model(arch="granite-3-2b"):
    cfg = get_smoke_config(arch)
    model = Model(cfg)
    params = model.init_params(jax.random.key(0))
    return cfg, model, params


def _requests(cfg, n, seed=0, new=6, lo=2, hi=9):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(lo, hi))).tolist(),
                    max_new_tokens=new)
            for i in range(n)]


def _assert_token_parity(a, b):
    for ra, rb in zip(a, b):
        assert ra.generated == rb.generated, (ra.uid, ra.generated,
                                              rb.generated)


# ----------------------------------------------------------------------
# dense parity anchor
# ----------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["granite-3-2b", "deepseek-v2-lite-16b"])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_dense_parity_anchor(arch, use_kernel):
    """page_size >= max_seq + one page per slot + greedy == the dense
    DecodeServer, token-for-token, with more requests than slots (the
    continuous-batching refill included)."""
    cfg, model, params = _model(arch)
    dense = DecodeServer(model, params, batch_size=2, max_seq_len=32)
    d = dense.run(_requests(cfg, 5))
    paged = PagedEngine(model, params, batch_size=2, max_seq_len=32,
                        page_size=32, num_pages=2, use_kernel=use_kernel)
    p = paged.run(_requests(cfg, 5))
    _assert_token_parity(d, p)
    # prompt ingestion never costs one pass per token: bulk mode is one
    # forward per admission, and the default chunked mode folds several
    # admissions into shared fused passes (3 observed here vs 5 bulk)
    assert 0 < paged.prefill_forwards <= 5
    assert paged.pool.metrics.preemptions == 0


@pytest.mark.parametrize("arch", ["xlstm-350m", "hymba-1.5b"])
def test_recurrent_archs_keep_dense_state(arch):
    """SSM/hybrid: recurrent state stays dense in the engine (only
    attention caches page) and the greedy outputs still match."""
    cfg, model, params = _model(arch)
    d = DecodeServer(model, params, batch_size=2,
                     max_seq_len=32).run(_requests(cfg, 4, new=5))
    p = PagedEngine(model, params, batch_size=2, max_seq_len=32,
                    page_size=8).run(_requests(cfg, 4, new=5))
    _assert_token_parity(d, p)


def test_scanned_layers_parity():
    """Production configs stack layers under lax.scan; the paged state,
    prefill scatter, and COW copy all address the extra leading layer
    dim — parity must hold there too (smoke configs are unscanned, so
    this flips the flag explicitly)."""
    cfg = get_smoke_config("granite-3-2b").with_overrides(scan_layers=True)
    model = Model(cfg)
    assert model.scan
    params = model.init_params(jax.random.key(0))
    d = DecodeServer(model, params, batch_size=2,
                     max_seq_len=24).run(_requests(cfg, 3, new=4))
    p = PagedEngine(model, params, batch_size=2, max_seq_len=24,
                    page_size=4).run(_requests(cfg, 3, new=4))
    _assert_token_parity(d, p)


def test_small_pages_parity_and_memory_accounting():
    """Multi-page sequences (page_size 4) keep token parity, and the
    in-use byte accounting matches the pool counters exactly."""
    cfg, model, params = _model()
    d = DecodeServer(model, params, batch_size=3,
                     max_seq_len=32).run(_requests(cfg, 7))
    eng = PagedEngine(model, params, batch_size=3, max_seq_len=32,
                      page_size=4)
    p = eng.run(_requests(cfg, 7))
    _assert_token_parity(d, p)
    m = eng.metrics()
    assert m["cache_in_use_bytes"] == \
        eng.pool.in_use * eng.cache_page_bytes()
    assert m["pool"]["peak_in_use"] <= eng.num_pages
    assert m["requests"] == 7 and m["latency_p95"] >= m["latency_p50"]
    eng.pool.check_invariants()


# ----------------------------------------------------------------------
# preemption
# ----------------------------------------------------------------------

def test_pool_exhaustion_preempts_and_completes():
    """A pool too small for the whole batch forces evictions; every
    request still finishes with its full token budget, and the greedy
    outputs equal an uncontended reference run (the re-queued prompt =
    prompt + generated reconstruction is exact under greedy)."""
    cfg, model, params = _model()
    # bulk mode: the prefill_forwards assert below counts one forward
    # per (re-)admission (chunked-mode preemption is covered in
    # tests/test_chunked_prefill.py)
    reference = PagedEngine(model, params, batch_size=3, max_seq_len=32,
                            page_size=4, prefill_chunk_tokens=0)
    ref = reference.run(_requests(cfg, 6, new=8))

    tight = PagedEngine(model, params, batch_size=3, max_seq_len=32,
                        page_size=4, num_pages=6, prefill_chunk_tokens=0)
    out = tight.run(_requests(cfg, 6, new=8))
    assert tight.pool.metrics.preemptions >= 1
    assert all(len(r.generated) == 8 for r in out)
    _assert_token_parity(ref, out)
    # preempted requests were re-prefilled: more prefill forwards than
    # admissions-from-queue alone
    assert tight.prefill_forwards > 6
    tight.pool.check_invariants()
    # finished requests returned their pages; only prefix-cache entries
    # still hold any, and spilling the cache drains the pool completely
    tight.prefix.drop_all()
    assert tight.pool.in_use == 0


def test_oversized_request_rejected():
    cfg, model, params = _model()
    eng = PagedEngine(model, params, batch_size=2, max_seq_len=16,
                      page_size=4)
    with pytest.raises(ValueError):
        eng.enqueue(Request(uid=0, prompt=[1] * 12, max_new_tokens=8))
    eng2 = PagedEngine(model, params, batch_size=1, max_seq_len=32,
                       page_size=4, num_pages=2)
    with pytest.raises(ValueError):
        eng2.enqueue(Request(uid=0, prompt=[1] * 10, max_new_tokens=8))


def test_empty_prompt_decodes_from_bos():
    cfg, model, params = _model()
    eng = PagedEngine(model, params, batch_size=2, max_seq_len=16,
                      page_size=4)
    req = Request(uid=0, prompt=[], max_new_tokens=3)
    eng.run([req])
    assert len(req.generated) == 3
    d = Request(uid=0, prompt=[], max_new_tokens=3)
    DecodeServer(model, params, batch_size=2, max_seq_len=16).run([d])
    assert req.generated == d.generated


# ----------------------------------------------------------------------
# shared-prefix copy-on-write
# ----------------------------------------------------------------------

def test_shared_prefix_bitwise_logits_and_cow():
    """Two same-length prompts with a common prefix share pages until
    the divergence point (full pages + one partial page COW'd on
    write); every decode logit is BITWISE equal to an engine with
    sharing disabled, and sharing strictly reduces page allocations."""
    cfg, model, params = _model()

    def reqs():
        # page_size 4: page0 fully shared, page1 holds one common token
        # (position 4) before the length-6 prompts diverge at position 5
        # — the second admission shares page1 partially and COWs it
        base = [5, 9, 3, 7, 2]
        return [Request(uid=0, prompt=base + [11], max_new_tokens=5),
                Request(uid=1, prompt=base + [12], max_new_tokens=5)]

    shared = PagedEngine(model, params, batch_size=2, max_seq_len=32,
                         page_size=4, trace_logits=True)
    unshared = PagedEngine(model, params, batch_size=2, max_seq_len=32,
                           page_size=4, share_prefixes=False,
                           trace_logits=True)
    a = shared.run(reqs())
    b = unshared.run(reqs())
    _assert_token_parity(a, b)
    for uid in (0, 1):
        np.testing.assert_array_equal(
            np.stack(shared.logit_trace[uid]),
            np.stack(unshared.logit_trace[uid]))
    assert shared.pool.metrics.prefix_hits >= 2     # page0 + partial page1
    assert shared.pool.metrics.cow_copies >= 1      # divergence mid-page
    assert shared.pool.metrics.allocs < unshared.pool.metrics.allocs


def test_identical_prompt_shares_all_pages_then_cows_on_decode():
    """Resubmitting an identical prompt shares every prompt page; the
    first decode write into the shared partial page goes through the
    COW gate, and both requests decode the same greedy continuation."""
    cfg, model, params = _model()
    prompt = [4, 8, 2, 6, 9, 1]
    eng = PagedEngine(model, params, batch_size=2, max_seq_len=32,
                      page_size=4)
    out = eng.run([Request(uid=0, prompt=list(prompt), max_new_tokens=5),
                   Request(uid=1, prompt=list(prompt), max_new_tokens=5)])
    assert out[0].generated == out[1].generated
    assert eng.pool.metrics.prefix_hits >= 2
    assert eng.pool.metrics.cow_copies >= 1
    single = PagedEngine(model, params, batch_size=1, max_seq_len=32,
                         page_size=4, share_prefixes=False)
    solo = single.run([Request(uid=0, prompt=list(prompt),
                               max_new_tokens=5)])
    assert solo[0].generated == out[0].generated


# ----------------------------------------------------------------------
# paged-attention kernel vs jnp oracle
# ----------------------------------------------------------------------

@settings(max_examples=6)
@given(seed=st.integers(0, 1000), page_size=st.sampled_from([4, 8, 16]),
       windowed=st.booleans())
def test_paged_attention_kernel_matches_ref(seed, page_size, windowed):
    key = jax.random.key(seed)
    B, H, kvh, hd, NP, M = 3, 4, 2, 8, 12, 3
    mk = lambda i, s: jax.random.normal(jax.random.fold_in(key, i), s)
    q = mk(0, (B, H, hd))
    k = mk(1, (NP, page_size, kvh, hd))
    v = mk(2, (NP, page_size, kvh, hd))
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.permutation(NP)[:B * M].reshape(B, M), jnp.int32)
    lens = jnp.asarray(rng.integers(1, M * page_size + 1, B), jnp.int32)
    window = 5 if windowed else None
    ref = paged_attention_ref(q, k, v, table, lens, window=window)
    out = paged_attention_op(q, k, v, table, lens, window=window,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_paged_state_specs_replicate_pages_shard_heads():
    """Production placement rule (launch/specs.paged_state_specs): pool
    page dims replicate over 'data' (any slot reads any page), only the
    trailing feature dims may shard over 'model'; recurrent and table
    leaves keep the dense batch-over-'data' rule."""
    from types import SimpleNamespace
    from jax.sharding import PartitionSpec as P
    from repro.launch.specs import paged_state_specs
    from repro.models.layers import KVCache
    from repro.models.mla import MLACache

    mesh = SimpleNamespace(shape={"data": 4, "model": 4},
                           axis_names=("data", "model"))
    kv = KVCache(k=jax.ShapeDtypeStruct((64, 16, 4, 32), jnp.float32),
                 v=jax.ShapeDtypeStruct((64, 16, 4, 32), jnp.float32))
    mla = MLACache(c_kv=jax.ShapeDtypeStruct((64, 16, 32), jnp.float32),
                   k_rope=jax.ShapeDtypeStruct((64, 16, 16), jnp.float32))
    recurrent = jax.ShapeDtypeStruct((8, 6, 24), jnp.float32)   # (B, ...)
    table = jax.ShapeDtypeStruct((8, 5), jnp.int32)
    specs = paged_state_specs(((kv, recurrent), mla, table), mesh)
    (kv_s, rec_s), mla_s, table_s = specs
    # hd=32 shards over 'model'; the (NP=64, P=16) page dims never
    # shard even though both divide the data axis
    assert kv_s.k == P(None, None, None, "model")
    assert mla_s.c_kv == P(None, None, "model")  # latent rank only
    assert rec_s == P("data", None, "model")     # dense batch rule
    assert table_s == P("data", None)
    # big pages are sub-tiled back under the budget
    big = paged_attention_vmem_bytes(page_size=4096, kvh=8, hd=128,
                                     num_q_heads=32)
    assert big < (5 << 20)
    small = paged_attention_vmem_bytes(page_size=16, kvh=2, hd=32,
                                       num_q_heads=4)
    assert small < (1 << 20)
