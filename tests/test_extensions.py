"""Extended coverage: DASHA-PP-SYNC-MVR (appendix G) and the
PL-condition analysis (paper Section F)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (QuadraticProblem, RandK, SNice, dasha_pp,
                        dasha_pp_mvr, dasha_pp_sync_mvr, theory)


def _constants(prob):
    L, L_hat, L_max, L_sigma = prob.smoothness()
    return theory.ProblemConstants(L=L, L_hat=L_hat, L_max=L_max,
                                   L_sigma=L_sigma, n=prob.n, m=prob.m,
                                   d=prob.d)


def test_sync_mvr_converges_and_beats_plain_mvr_tail(small_problem):
    """Appendix G: the resync removes compressed-estimator drift; with
    identical (gamma, a, b) SYNC-MVR's tail gradient norm is no worse
    than ~plain MVR's."""
    prob = small_problem
    comp = RandK(k=max(1, prob.d // 8))
    samp = SNice(n=prob.n, s=4)
    c = _constants(prob)
    hp = theory.dasha_pp_mvr(c, comp.omega(prob.d), samp.p_a, samp.p_aa, 2)
    kw = dict(gamma=hp.gamma * 64, a=hp.a, b=hp.b, batch_size=2)
    x0 = jnp.zeros(prob.d)
    plain = dasha_pp_mvr(prob, comp, samp, **kw)
    sync = dasha_pp_sync_mvr(prob, comp, samp, p_sync=0.2, **kw)
    _, m1 = jax.jit(lambda k: plain.run(k, x0, 1200))(jax.random.key(1))
    _, m2 = jax.jit(lambda k: sync.run(k, x0, 1200))(jax.random.key(1))
    t1 = float(np.median(np.asarray(m1.grad_norm_sq)[-100:]))
    t2 = float(np.median(np.asarray(m2.grad_norm_sq)[-100:]))
    assert np.isfinite(t2) and t2 < 0.05 * float(m2.grad_norm_sq[0])
    assert t2 < 3.0 * t1, (t1, t2)
    # resync rounds cost extra uncompressed bits — accounted
    assert float(np.sum(np.asarray(m2.bits_sent))) > \
        float(np.sum(np.asarray(m1.bits_sent)))


def test_sync_mvr_unbiased_resync():
    """The 1/p_a-debiased resync keeps E[g] consistent: after one resync
    round with full participation the server estimator equals the mean
    of the node estimators."""
    prob = QuadraticProblem.random(jax.random.key(0), n=6, d=10)
    comp = RandK(k=3)
    samp = SNice(n=6, s=6)   # full participation -> deterministic resync
    alg = dasha_pp_sync_mvr(prob, comp, samp, gamma=0.01, a=0.1, b=0.5,
                            batch_size=1, p_sync=1.0)
    st = alg.init(jax.random.key(1), jnp.zeros(10))
    st2, _ = jax.jit(alg.step)(jax.random.key(2), st)
    np.testing.assert_allclose(np.asarray(st2.g),
                               np.asarray(jnp.mean(st2.g_i, axis=0)),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(st2.g_i), np.asarray(st2.h_i),
                               rtol=1e-5, atol=1e-6)


def test_pl_linear_convergence():
    """Section F: on a strongly-convex quadratic (PL with mu = lambda_min)
    DASHA-PP converges linearly at >= the predicted rate order."""
    prob = QuadraticProblem.random(jax.random.key(3), n=6, d=10, cond=4.0)
    c = _constants(prob)
    mu = float(jnp.linalg.eigvalsh(jnp.mean(prob.A, 0))[0])
    comp = RandK(k=4)
    samp = SNice(n=6, s=3)
    omega = comp.omega(prob.d)
    hp, rate = theory.dasha_pp_pl(c, omega, samp.p_a, samp.p_aa, mu)
    assert 0.0 < rate < 1.0
    alg = dasha_pp(prob, comp, samp, gamma=hp.gamma, a=hp.a, b=hp.b)
    x0 = jnp.ones(prob.d) * 2.0
    _, mets = jax.jit(lambda k: alg.run(k, x0, 3000))(jax.random.key(4))
    g = np.asarray(mets.grad_norm_sq)
    # log-linear fit over the decaying stretch -> empirical contraction
    seg = g[100:2500]
    seg = seg[seg > 1e-20]
    t = np.arange(seg.size)
    slope = np.polyfit(t, np.log(seg), 1)[0]
    emp_rate = float(np.exp(slope / 2))     # gnorm^2 ~ rate^{2t}
    assert emp_rate < 1.0, "not linearly converging"
    # the guaranteed factor upper-bounds the observed contraction
    assert emp_rate <= rate + 1e-4, (emp_rate, rate)
    assert g[-1] < 1e-9 * g[0]              # linear convergence reached
    # rounds-to-eps helper is consistent
    T = theory.pl_rounds_to_eps(c, omega, samp.p_a, samp.p_aa, mu,
                                eps=1e-6, delta0=float(g[0]))
    assert T > 0


def test_pl_rate_improves_with_participation():
    c = theory.ProblemConstants(L=1.0, L_hat=1.2, n=16, m=1, d=50)
    rates = [theory.dasha_pp_pl(c, 3.0, pa, pa * pa, mu=0.1)[1]
             for pa in (0.1, 0.5, 1.0)]
    assert rates[0] > rates[1] > rates[2]   # more participation -> faster
