"""Page-pool allocator invariants (serving/pages.py, DESIGN.md §11).

Property-tested via tests/_hypo.py (hypothesis when installed, the
deterministic fallback otherwise): random alloc/retain/release/writable
sequences must conserve pages, keep refcounts consistent, and never
leave a page simultaneously free and referenced.
"""
import random

import pytest
from _hypo import given, settings, st   # hypothesis or deterministic fallback

from repro.serving.pages import PagePool, PrefixCache


# ----------------------------------------------------------------------
# PagePool
# ----------------------------------------------------------------------

@settings(max_examples=20)
@given(num_pages=st.integers(1, 12), seed=st.integers(0, 10_000),
       steps=st.integers(1, 120))
def test_pool_random_ops_keep_invariants(num_pages, seed, steps):
    rng = random.Random(seed)
    pool = PagePool(num_pages, page_size=4)
    held = []                      # one entry per reference we hold
    for _ in range(steps):
        op = rng.random()
        if op < 0.4:
            pid = pool.alloc()
            if pid is None:
                assert pool.free_pages == 0
            else:
                assert pool.refcount(pid) == 1
                held.append(pid)
        elif op < 0.6 and held:
            pid = rng.choice(held)
            pool.retain(pid)
            held.append(pid)
        elif op < 0.85 and held:
            pid = held.pop(rng.randrange(len(held)))
            pool.release(pid)
        elif held:
            pid = rng.choice(held)
            new_pid, copied = pool.writable(pid)
            if new_pid is None:
                assert pool.refcount(pid) > 1 and pool.free_pages == 0
            else:
                assert pool.refcount(new_pid) >= 1
                if copied:
                    assert new_pid != pid
                    held.remove(pid)
                    held.append(new_pid)
                else:
                    assert new_pid == pid and pool.refcount(pid) == 1
        pool.check_invariants()
    # every reference we hold maps to a live page; full drain frees all
    for pid in held:
        pool.release(pid)
    pool.check_invariants()
    assert pool.free_pages == num_pages
    assert pool.in_use == 0


def test_pool_exhaustion_and_alloc_n():
    pool = PagePool(3, page_size=8)
    pages = pool.alloc_n(3)
    assert sorted(pages) == [0, 1, 2]
    assert pool.alloc() is None
    assert pool.alloc_n(1) is None
    assert pool.metrics.alloc_failures == 2
    pool.release(pages[1])
    assert pool.alloc() == 1       # LIFO free list reuses the freed page
    pool.check_invariants()


def test_pool_writable_cow_semantics():
    pool = PagePool(4, page_size=8)
    a = pool.alloc()
    same, copied = pool.writable(a)
    assert (same, copied) == (a, False)       # exclusive: no copy
    pool.retain(a)                            # now shared
    fresh, copied = pool.writable(a)
    assert copied and fresh != a
    assert pool.refcount(fresh) == 1
    assert pool.refcount(a) == 1              # the other holder remains
    assert pool.metrics.cow_copies == 1
    pool.check_invariants()


def test_pool_refcount_errors():
    pool = PagePool(2, page_size=4)
    with pytest.raises(ValueError):
        pool.release(0)
    with pytest.raises(ValueError):
        pool.retain(1)


# ----------------------------------------------------------------------
# PrefixCache
# ----------------------------------------------------------------------

def test_prefix_cache_full_and_partial_match():
    pool = PagePool(8, page_size=4)
    cache = PrefixCache(pool)
    prompt = [5, 9, 3, 7, 2, 8]               # page0 full, page1 covers 2
    pages = pool.alloc_n(2)
    cache.register(prompt, pages)
    # full-page + partial sub-length entries, each holding a reference
    assert pool.refcount(pages[0]) == 2
    assert pool.refcount(pages[1]) == 3       # c=1 and c=2 entries

    # identical prompt: shares both pages, stops at the partial page
    shared, n = cache.match(list(prompt))
    assert n == 6 and [p for p, _ in shared] == pages
    assert [c for _, c in shared] == [4, 2]
    for pid, _ in shared:
        pool.release(pid)

    # divergence mid-page: shares up to the divergence point only
    shared, n = cache.match([5, 9, 3, 7, 2, 99, 1])
    assert n == 5 and [(p, c) for p, c in shared] == [(pages[0], 4),
                                                      (pages[1], 1)]
    for pid, _ in shared:
        pool.release(pid)

    # divergence inside the first page: nothing shareable
    shared, n = cache.match([5, 1, 3, 7])
    assert (shared, n) == ([], 0)
    assert pool.metrics.prefix_hits == 4


def test_prefix_cache_eviction_returns_pages():
    pool = PagePool(4, page_size=4)
    cache = PrefixCache(pool)
    prompt = [1, 2, 3, 4]
    (pid,) = pool.alloc_n(1)
    cache.register(prompt, [pid])
    pool.release(pid)              # only the cache holds it now
    assert pool.free_pages == 3
    assert cache.evict(1) == 1     # entry dropped, page back in the pool
    assert pool.free_pages == 4
    assert len(cache) == 0
    pool.check_invariants()


def test_prefix_cache_eviction_skips_shared_holders():
    pool = PagePool(4, page_size=4)
    cache = PrefixCache(pool)
    (pid,) = pool.alloc_n(1)
    cache.register([1, 2, 3, 4], [pid])
    # the request still holds the page: eviction frees nothing but the
    # cache entry is gone and the request's reference survives
    assert cache.evict(1) == 0
    assert len(cache) == 0
    assert pool.refcount(pid) == 1
    pool.check_invariants()
